"""Observability subsystem: metrics registry + Prometheus exposition,
deterministic span tracing, critical-path reconciliation against the
fleet's pipeline latency, hot-loop profiling, report rendering, and the
obs-on == obs-off bit-identity contract.  Also pins the PR-6
TelemetryWindow rejections/swaps delta semantics."""
import json

import pytest

from repro.cluster import FleetSimulator, TransferModel
from repro.cluster.telemetry import FleetTelemetry, TelemetryWindow
from repro.obs import (HotLoopProfiler, MetricsError, MetricsRegistry, Obs,
                       SpanError, SpanTracer, critical_path, load_jsonl,
                       parse_prometheus, pipeline_tails, validate_span)
from repro.obs.report import render_report

from test_cluster import cascade_fleet, small_fleet
from test_slo import SLO_CFG, tiered_fleet


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("frames_total", "frames", ("node",))
    c.inc(3, node=0)
    c.inc(2, node=0)
    c.inc(1, node=1)
    g = reg.gauge("pressure", "controller pressure")
    g.set(0.25)
    g.inc(0.5)
    h = reg.histogram("latency_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    snap = reg.snapshot()
    assert snap["frames_total"]["samples"] == [
        {"labels": {"node": "0"}, "value": 5.0},
        {"labels": {"node": "1"}, "value": 1.0}]
    assert snap["pressure"]["samples"][0]["value"] == 0.75
    hs = snap["latency_seconds"]["samples"][0]
    assert hs["count"] == 3 and hs["sum"] == 5.55
    assert hs["buckets"] == {"0.1": 1, "1": 2}


def test_metrics_registry_get_or_create_and_mismatch():
    reg = MetricsRegistry()
    c1 = reg.counter("x_total", "x", ("a",))
    assert reg.counter("x_total", "x", ("a",)) is c1
    with pytest.raises(MetricsError):
        reg.gauge("x_total", "x")            # kind mismatch
    with pytest.raises(MetricsError):
        reg.counter("x_total", "x", ("b",))  # label-set mismatch
    with pytest.raises(MetricsError):
        c1.inc(1)                            # missing label
    with pytest.raises(MetricsError):
        c1.inc(-1, a=1)                      # counters only go up
    with pytest.raises(MetricsError):
        reg.counter("bad name", "x")         # invalid metric name


def test_prometheus_export_parses_and_matches():
    reg = MetricsRegistry()
    reg.counter("jobs_total", "jobs done", ("node", "model")).inc(
        7, node=2, model='det"x\\y')         # label escaping exercised
    reg.histogram("wait_seconds", "wait", buckets=(0.5,)).observe(0.2)
    samples = parse_prometheus(reg.to_prometheus())
    by_name = {}
    for s in samples:
        by_name.setdefault(s["name"], []).append(s)
    assert by_name["jobs_total"][0]["labels"] == \
        {"node": "2", "model": 'det"x\\y'}
    assert by_name["jobs_total"][0]["value"] == 7.0
    # histogram expands to cumulative buckets (+Inf), _sum and _count
    les = [s["labels"]["le"] for s in by_name["wait_seconds_bucket"]]
    assert les == ["0.5", "+Inf"]
    assert by_name["wait_seconds_sum"][0]["value"] == 0.2
    assert by_name["wait_seconds_count"][0]["value"] == 1.0


def test_parse_prometheus_rejects_garbage():
    with pytest.raises(MetricsError):
        parse_prometheus("what even is this line\n")
    with pytest.raises(MetricsError):
        parse_prometheus("ok_metric not_a_number\n")


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_span_ids_deterministic_counter_keyed():
    def trace():
        tr = SpanTracer()
        a = tr.open("job", 0.0, uid="j0")
        tr.event("place", 0.1, stream=1)
        tr.close(a, 0.5, outcome="done")
        tr.finish(1.0)
        return tr.to_records()
    assert trace() == trace()                # no wall clock, no RNG
    sids = [r["sid"] for r in trace()]
    assert sids == sorted(sids) == list(range(len(sids)))


def test_span_close_unknown_and_unfinished():
    tr = SpanTracer()
    with pytest.raises(SpanError):
        tr.close(99, 1.0)
    sid = tr.open("job", 0.0, uid="j1")
    tr.finish(2.0)
    rec = tr.to_records()[0]
    assert rec["sid"] == sid
    assert rec["t1"] == 2.0
    assert rec["attrs"]["outcome"] == "unfinished"
    validate_span(rec)


def test_span_jsonl_roundtrip(tmp_path):
    tr = SpanTracer()
    tr.event("stream", 0.25, stream=3)
    tr.span("xfer", 0.3, 0.4, src=0, dst=1, nbytes=1024)
    p = tmp_path / "spans.jsonl"
    tr.dump_jsonl(str(p))
    assert load_jsonl(str(p)) == tr.to_records()


# ---------------------------------------------------------------------------
# obs on/off bit-identity on fleet runs
# ---------------------------------------------------------------------------

def test_obs_disabled_leaves_no_hooks():
    fs = FleetSimulator(small_fleet(dur=0.5), "score", duration_s=0.5,
                        seed=2)
    assert fs.obs is None and fs._tracer is None and fs._metrics is None
    fs.run()
    for node in fs.nodes.values():
        assert node.sim.obs is None
    assert fs.stream_seconds > 0.0           # tracked independently of obs


def test_obs_enabled_run_bit_identical():
    scn = small_fleet(churn=True)
    bare = FleetSimulator(scn, "score", duration_s=1.5, seed=2)
    r0 = bare.run()
    fs = FleetSimulator(scn, "score", duration_s=1.5, seed=2, obs=True)
    r1 = fs.run()
    assert r1.uxcost == r0.uxcost
    assert r1.frames == r0.frames
    assert r1.migrations == r0.migrations
    assert r1.stream_seconds == r0.stream_seconds
    # placements identical too (same stream -> node map at the end)
    assert fs.stream_node == bare.stream_node
    recs = fs.obs.tracer.to_records()
    assert recs
    for r in recs:
        validate_span(r)
    kinds = {r["kind"] for r in recs}
    assert {"job", "place", "stream", "node_join"} <= kinds


def test_obs_selective_facilities():
    fs = FleetSimulator(small_fleet(dur=0.5), "score", duration_s=0.5,
                        seed=2, obs={"spans": False, "profile": False})
    fs.run()
    assert fs.obs.tracer is None and fs.obs.profiler is None
    snap = fs.obs.metrics.snapshot()
    assert snap["fleet_placements_total"]["samples"]
    assert "fleet_uxcost" in snap


def test_obs_shared_bundle_and_export(tmp_path):
    obs = Obs.make(True)
    FleetSimulator(small_fleet(dur=0.5), "score", duration_s=0.5, seed=2,
                   obs=obs).run()
    paths = obs.export(str(tmp_path))
    assert set(paths) == {"spans", "metrics_prom", "metrics_json",
                          "profile"}
    assert load_jsonl(paths["spans"])
    assert parse_prometheus(open(paths["metrics_prom"]).read())
    prof = json.load(open(paths["profile"]))
    assert prof["total_wall_s"] > 0.0


# ---------------------------------------------------------------------------
# critical-path reconciliation with overall_pipeline_latency
# ---------------------------------------------------------------------------

def _assert_paths_reconcile(fs, result):
    recs = fs.obs.tracer.to_records()
    tails = pipeline_tails(recs)
    assert len(tails) == result.pipe_frames
    total = 0.0
    for tail in tails:
        cp = critical_path(recs, tail_uid=tail["attrs"]["uid"])
        seg_sum = sum(s["t1"] - s["t0"] for s in cp["segments"])
        assert abs(seg_sum - cp["total_s"]) < 1e-9   # telescoping
        total += cp["total_s"]
    mean = total / len(tails) if tails else 0.0
    assert abs(mean - result.pipeline_latency_s) < 1e-9
    return recs


def test_critical_path_reconciles_whole_pipeline():
    fs = FleetSimulator(cascade_fleet(), "score", duration_s=1.5, seed=3,
                        obs=True)
    _assert_paths_reconcile(fs, fs.run())


def test_critical_path_reconciles_stage_split():
    fs = FleetSimulator(cascade_fleet(), "score", duration_s=1.5, seed=3,
                        obs=True, split_stages=True,
                        transfer=TransferModel(
                            link_bandwidth_bytes_s=1.25e9))
    r = fs.run()
    recs = _assert_paths_reconcile(fs, r)
    # cross-node trigger edges surface as xfer spans and transfer segments
    assert sum(1 for x in recs if x["kind"] == "xfer") \
        == r.trigger_transfers
    if r.trigger_transfers:
        seg_names = set()
        for tail in pipeline_tails(recs):
            cp = critical_path(recs, tail_uid=tail["attrs"]["uid"])
            seg_names |= set(cp["by_seg"])
        assert "transfer" in seg_names


def test_critical_path_reconciles_slo_overload():
    fs = FleetSimulator(tiered_fleet(), "score", duration_s=1.0, seed=3,
                        slo=SLO_CFG, slo_every_s=0.1, obs=True)
    r = fs.run()
    recs = _assert_paths_reconcile(fs, r)
    # the controller's decisions are traced with pressure-term attribution
    admits = [x for x in recs if x["kind"] == "admit"]
    assert admits
    for a in admits:
        terms = a["attrs"]["terms"]
        assert abs(terms["base"] + terms["dlv"] + terms["backlog"]
                   + terms["latency"] - a["attrs"]["pressure"]) < 1e-9


def test_critical_path_requires_done_tail():
    with pytest.raises(SpanError):
        critical_path([{"sid": 0, "kind": "job", "t0": 0.0, "t1": 1.0,
                        "attrs": {"uid": "j0", "tail": False,
                                  "outcome": "done"}}])


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------

def test_profiler_records_hot_loop_keys():
    fs = FleetSimulator(small_fleet(dur=0.5), "score", duration_s=0.5,
                        seed=2, obs=True)
    r = fs.run()
    prof = fs.obs.profiler
    assert prof.total_wall_s > 0.0
    assert any(k.startswith("fleet.") for k in prof.counts)
    assert any(k.startswith("node.") for k in prof.counts)
    assert prof.streams_per_wall_s(r.stream_seconds) > 0.0
    top = prof.top(3)
    assert len(top) <= 3
    assert top == sorted(top, key=lambda kv: -kv[1])
    assert "us/call" in prof.table(5)


def test_profiler_snapshot_shape():
    prof = HotLoopProfiler()
    prof.start_run()
    t0 = prof.t0()
    prof.add("x", t0)
    prof.stop_run()
    snap = prof.snapshot()
    assert snap["keys"]["x"]["count"] == 1
    assert snap["keys"]["x"]["wall_s"] >= 0.0
    assert snap["total_wall_s"] > 0.0


# ---------------------------------------------------------------------------
# report rendering
# ---------------------------------------------------------------------------

def test_render_report_all_sections():
    fs = FleetSimulator(tiered_fleet(), "score", duration_s=1.0, seed=3,
                        slo=SLO_CFG, slo_every_s=0.1, obs=True)
    fs.run()
    text = render_report(fs.obs.tracer.to_records(),
                         fs.obs.metrics.snapshot(),
                         fs.obs.profiler.snapshot(), title="T")
    for section in ("# T", "## Fleet timeline",
                    "## Slowest pipelines (critical paths)",
                    "## Pressure-law attribution", "## Per-tier DLV",
                    "## Hot-loop profile"):
        assert section in text


def test_render_report_degrades_per_artifact():
    text = render_report(None, None, {"total_wall_s": 0.0, "keys": {}})
    assert "## Hot-loop profile" in text
    assert "## Fleet timeline" not in text


# ---------------------------------------------------------------------------
# PR-6 TelemetryWindow rejections/swaps delta semantics
# ---------------------------------------------------------------------------

def test_telemetry_window_rejection_swap_deltas_exact():
    tel = FleetTelemetry()
    w1 = tel.observe(0.5, {}, migrations=1, xfer_energy_j=0.0,
                     departures=2, rejections=3, swaps=4)
    w2 = tel.observe(1.0, {}, migrations=4, xfer_energy_j=0.0,
                     departures=2, rejections=8, swaps=9)
    w3 = tel.observe(1.5, {}, migrations=4, xfer_energy_j=0.0,
                     departures=2, rejections=8, swaps=9)
    # cumulative counters in, exact per-window deltas out
    assert (w1.departures, w1.rejections, w1.swaps) == (2, 3, 4)
    assert (w2.departures, w2.rejections, w2.swaps) == (0, 5, 5)
    assert (w3.departures, w3.rejections, w3.swaps) == (0, 0, 0)
    assert w2.migrations == 3
    # deltas re-merge to the cumulative totals
    assert sum(w.rejections for w in tel.windows) == 8
    assert sum(w.swaps for w in tel.windows) == 9


def test_telemetry_window_empty_zero_frames():
    tel = FleetTelemetry()
    w = tel.observe(0.1, {}, migrations=0, xfer_energy_j=0.0,
                    rejections=7, swaps=2)
    assert w.empty and w.frames == 0
    assert (w.rejections, w.swaps) == (7, 2)  # counters survive emptiness
    assert w.dlv_rate == 0.0 and w.uxcost == 0.0


def test_telemetry_window_live_fleet_deltas_sum_to_totals():
    fs = FleetSimulator(tiered_fleet(), "score", duration_s=1.0, seed=3,
                        slo=SLO_CFG, slo_every_s=0.1)
    r = fs.run()
    assert r.rejections + r.swaps > 0        # the controller acted
    wins = fs._slo_tel.windows
    assert wins
    assert sum(w.rejections for w in wins) <= r.rejections
    assert sum(w.swaps for w in wins) <= r.swaps
    # each window's delta is non-negative and never exceeds the totals
    for w in wins:
        assert w.rejections >= 0 and w.swaps >= 0


def test_telemetry_window_is_frozen():
    with pytest.raises(Exception):
        w = TelemetryWindow(
            t0=0.0, t1=1.0, frames=0, violated=0, dlv_rate=0.0,
            uxcost=0.0, node_dlv={}, node_frames={}, backlog_p50=0.0,
            backlog_p90=0.0, backlog_max=0.0, migrations=0, xfer_j=0.0,
            stream_uxcost={})
        w.rejections = 5
