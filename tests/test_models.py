"""Per-architecture smoke tests: reduced same-family configs, forward /
train step on CPU, shape + finiteness assertions, decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import model as M
from repro.training import TrainConfig, OptimConfig, build_train_step, \
    init_train_state

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, b=2, s=16):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    fr = (jax.random.normal(KEY, (b, cfg.frontend_tokens, cfg.frontend_dim),
                            jnp.float32) if cfg.frontend else None)
    return tokens, fr


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params = M.init_params(KEY, cfg)
    tokens, fr = _inputs(cfg)
    logits, aux = M.forward(params, cfg, tokens, fr)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = dataclasses.replace(smoke_config(arch), dtype="float32")
    tcfg = TrainConfig(optim=OptimConfig(learning_rate=1e-3, warmup_steps=1,
                                         total_steps=10))
    step = jax.jit(build_train_step(cfg, tcfg))
    state = init_train_state(KEY, cfg, tcfg)
    tokens, fr = _inputs(cfg, b=2, s=8)
    batch = {"tokens": tokens, "labels": tokens}
    if fr is not None:
        batch["frontend"] = fr
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward_fp32(arch):
    """prefill+decode_step == forward on the extended sequence (exact in
    fp32; bf16 diverges numerically through deep residual paths)."""
    cfg = dataclasses.replace(smoke_config(arch), dtype="float32")
    params = M.init_params(KEY, cfg)
    b, s = 2, 12
    tokens, fr = _inputs(cfg, b, s)
    cache = M.init_cache(cfg, b, s + 2, jnp.float32)
    plogits, cache = M.prefill(params, cfg, tokens, cache, fr)
    logits, _ = M.forward(params, cfg, tokens, fr)
    np.testing.assert_allclose(np.asarray(plogits), np.asarray(logits),
                               atol=1e-4, rtol=1e-4)
    nxt = jnp.argmax(plogits[:, -1], -1).astype(jnp.int32)[:, None]
    pos = jnp.full((b,), s, jnp.int32)
    dlogits, _ = M.decode_step(params, cfg, nxt, cache, pos)
    ext = jnp.concatenate([tokens, nxt], axis=1)
    flogits, _ = M.forward(params, cfg, ext, fr)
    np.testing.assert_allclose(np.asarray(dlogits[:, 0]),
                               np.asarray(flogits[:, -1]),
                               atol=1e-3, rtol=1e-3)


def test_scan_equals_loop():
    cfg = smoke_config("gemma2-2b")
    cfg_scan = dataclasses.replace(cfg, num_layers=4, scan_layers=True,
                                   dtype="float32")
    cfg_loop = dataclasses.replace(cfg_scan, scan_layers=False)
    params = M.init_params(KEY, cfg_scan)
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    l1, _ = M.forward(params, cfg_scan, tokens)
    l2, _ = M.forward(params, cfg_loop, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_remat_preserves_values():
    cfg = dataclasses.replace(smoke_config("qwen1.5-4b"), dtype="float32")
    params = M.init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    l1, _ = M.forward(params, cfg, tokens)
    for remat in ("dots", "full"):
        cfg_r = dataclasses.replace(cfg, remat=remat)
        l2, _ = M.forward(params, cfg_r, tokens)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_local_attention_masks_differ_from_global():
    """gemma2's local layers must actually restrict the receptive field."""
    cfg = dataclasses.replace(smoke_config("gemma2-2b"), dtype="float32",
                              local_window=2)
    params = M.init_params(KEY, cfg)
    b, s = 1, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    logits1, _ = M.forward(params, cfg, tokens)
    # perturbing token 0 must NOT change position s-1 through local-only
    # paths... it can still flow through global layers; instead check the
    # window masks by comparing against window=s (=global everywhere)
    cfg_g = dataclasses.replace(cfg, local_window=s)
    logits2, _ = M.forward(params, cfg_g, tokens)
    assert not np.allclose(np.asarray(logits1), np.asarray(logits2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (published) config fields match the assignment table."""
    cfg = get_config(arch)
    expected = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)
    if arch == "phi3.5-moe-42b-a6.6b":
        assert (cfg.num_experts, cfg.num_experts_per_tok) == (16, 2)
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.num_experts, cfg.num_experts_per_tok) == (128, 8)
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state == 64 and cfg.shared_attn_every == 6
    if arch == "mamba2-130m":
        assert cfg.ssm_state == 128
