"""Throughput perf smoke: the fleet simulator must clear a committed
simulated-stream-seconds-per-wall-second floor on a fixed mid-size run.

This is a *smoke* floor, not a benchmark: it is set ~3.5x below the
throughput this scenario achieves on the reference CI machine (typ.
~250 stream-s/wall-s vectorized, ~200 with the scalar oracles forced),
so it only trips on pathological regressions — an accidental O(N^2)
rescan, a disabled fast path plus a large constant-factor hit, a
per-frame allocation storm.  Finer-grained drift is tracked by the
nightly lane instead: ``scripts/check_bench.py`` records the CI sweep's
``streams_per_wall_s`` into the trajectory trend series every run and,
under ``--gate-throughput``, enforces the absolute floors committed in
``benchmarks/baselines/ci_baseline.json`` (``throughput_floors``).

Best-of-3 is deliberate: wall-clock on shared CI runners is noisy and a
perf *floor* test must only fail when the code is slow, not when the
machine is busy.  The first run also warms the cost-table and row
caches, mirroring steady-state simulator use.
"""
from __future__ import annotations

import time

import pytest

from repro.cluster import (FleetScenarioBuilder, FleetSimulator,
                           FuzzSpec, LifecycleFuzz, TransferModel)

#: committed floor, simulated stream-seconds per wall-second (best-of-3)
FLOOR_STREAMS_PER_WALL_S = 70.0

#: exact stream-seconds this fixed scenario simulates — pinned so a
#: behavior change can't silently shrink the workload under the floor
EXPECTED_STREAM_SECONDS = 61.617

SYSTEMS_MIX = ("4K_2WS", "8K_2OS", "4K_1WS2OS", "8K_1OS2WS")


def _build_scenario():
    b = FleetScenarioBuilder("perf_smoke")
    nids = [b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)]) for i in range(8)]
    b.node_drain(nids[0], at=0.5)
    b.fuzz_streams(FuzzSpec(
        n_streams=96, seed=7, t0=0.0, t1=0.6, fps_scale=0.25,
        lifecycle=LifecycleFuzz(depart_frac=0.3, rejoin_frac=0.3,
                                t0=0.4, t1=0.9)))
    return b.build()


def _one_run() -> float:
    fs = FleetSimulator(
        _build_scenario(), "score", duration_s=1.0, seed=7,
        transfer=TransferModel(link_bandwidth_bytes_s=1.25e9),
        rebalance_every_s=0.3)
    t0 = time.perf_counter()
    r = fs.run()
    wall = time.perf_counter() - t0
    assert abs(r.stream_seconds - EXPECTED_STREAM_SECONDS) < 0.01, \
        "perf-smoke workload changed — re-derive the floor"
    return r.stream_seconds / wall


@pytest.mark.perf
def test_fleet_throughput_floor():
    best = max(_one_run() for _ in range(3))
    assert best >= FLOOR_STREAMS_PER_WALL_S, (
        f"fleet throughput {best:.1f} stream-s/wall-s fell below the "
        f"committed smoke floor {FLOOR_STREAMS_PER_WALL_S} — a >3x "
        "slowdown vs the reference machine; profile the inner loop "
        "(core/simulator dispatch, cluster/node drain, router scoring)")
