"""Level-1 scheduler behaviour: MapScore semantics, frame drop conditions,
adaptivity convergence, baseline sanity, end-to-end simulator invariants."""
import numpy as np
import pytest

from repro.core import (SYSTEMS, build_scenario, dream_full,
                        optimize_params, run_planaria, run_sim)
from repro.core.baselines import (FCFSScheduler, StaticFCFSScheduler,
                                  VeltairLikeScheduler)
from repro.core.costmodel import build_cost_table
from repro.core.mapscore import MapScoreParams, mapscore
from repro.core.types import Dataflow, Layer, ModelGraph, OpType
from repro.core import zoo


def _table(n_accs=2):
    m = ModelGraph("m", layers=(
        Layer("fc1", OpType.FC, K=256, C=256),
        Layer("fc2", OpType.FC, K=64, C=256),
    ))
    accs = tuple(SYSTEMS["4K_1WS2OS"][:n_accs])
    return build_cost_table(m, accs)


def test_urgency_increases_as_deadline_nears():
    t = _table()
    kw = dict(table=t, next_layer=0, remaining=np.array([0, 1]),
              t_cmpl=0.0, prev_out_bytes=np.zeros(2),
              same_model=np.zeros(2, bool), params=MapScoreParams(0.0, 0.0))
    early = mapscore(t_curr=0.0, deadline=1.0, **kw)
    late = mapscore(t_curr=0.9, deadline=1.0, **kw)
    assert np.all(late >= early)


def test_latpref_prefers_faster_accelerator():
    t = _table()
    s = mapscore(table=t, next_layer=0, remaining=np.array([0]),
                 t_curr=0.0, t_cmpl=0.0, deadline=0.5,
                 prev_out_bytes=np.zeros(2), same_model=np.zeros(2, bool),
                 params=MapScoreParams(0.0, 0.0))
    lat = t.lat[:, 0]
    assert np.argmax(s) == np.argmin(lat)


def test_starvation_grows_with_queue_time():
    t = _table()
    kw = dict(table=t, next_layer=0, remaining=np.array([0]),
              t_curr=1.0, deadline=10.0, prev_out_bytes=np.zeros(2),
              same_model=np.zeros(2, bool), params=MapScoreParams(2.0, 0.0))
    fresh = mapscore(t_cmpl=1.0, **kw)
    starved = mapscore(t_cmpl=0.0, **kw)
    assert np.all(starved >= fresh)


def test_energy_score_penalizes_context_switch():
    t = _table()
    kw = dict(table=t, next_layer=0, remaining=np.array([0]),
              t_curr=0.0, t_cmpl=0.0, deadline=0.5,
              prev_out_bytes=np.full(2, 1e6),
              params=MapScoreParams(0.0, 1.0))
    same = mapscore(same_model=np.ones(2, bool), **kw)
    switch = mapscore(same_model=np.zeros(2, bool), **kw)
    assert np.all(same >= switch)


# ---------------------------------------------------------------------------
# simulator end-to-end invariants
# ---------------------------------------------------------------------------

SCHEDULERS = {
    "FCFS": FCFSScheduler,
    "Static": StaticFCFSScheduler,
    "Veltair": VeltairLikeScheduler,
    "DREAM": dream_full,
}


@pytest.mark.parametrize("sched", list(SCHEDULERS))
def test_sim_runs_and_accounts_all_frames(sched):
    scn = build_scenario("AR_Call", 0.5)
    r = run_sim(scn, "4K_1WS2OS", SCHEDULERS[sched], duration_s=2.0)
    assert r.frames > 0
    assert 0.0 <= r.dlv_rate <= 1.0
    assert 0.0 <= r.norm_energy <= 1.0 + 1e-9
    assert r.uxcost >= 0.0


def test_sim_deterministic_given_seed():
    scn = build_scenario("VR_Gaming", 0.5)
    r1 = run_sim(scn, "4K_1WS2OS", dream_full, duration_s=2.0, seed=3)
    r2 = run_sim(scn, "4K_1WS2OS", dream_full, duration_s=2.0, seed=3)
    assert r1.uxcost == r2.uxcost and r1.frames == r2.frames


def test_planaria_runs():
    scn = build_scenario("Drone_Outdoor", 0.5)
    r = run_planaria(scn, "4K_1WS2OS", duration_s=2.0)
    assert r.frames > 0 and r.uxcost >= 0


def test_frame_drop_bounded_rate():
    """Condition 4: drops per model bounded by 2 per 10-frame window."""
    scn = build_scenario("AR_Social", 0.9)
    r = run_sim(scn, "4K_2OS", dream_full, duration_s=4.0)
    # global check: drops can never exceed the bound * frames
    assert r.drops <= 0.25 * r.frames + 5


def test_supernet_switch_mechanism():
    """Section 4.5.1: at the switch point, a job that cannot meet its
    deadline is swapped to the heaviest variant that can; a job with ample
    slack keeps the original. (End-to-end switch *rates* are emergent and
    load-dependent — see benchmarks.fig14 — so the mechanism is unit-tested
    deterministically here.)"""
    from repro.core.simulator import Simulator
    scn = build_scenario("VR_Gaming", 0.5)
    ctx_idx = scn.model_index("ctx_ofa")

    def fresh_job(slack):
        sim = Simulator(scn, "4K_1WS2OS", dream_full(), duration_s=1.0)
        job = sim._create_job(ctx_idx, t=0.0)
        job.deadline = slack
        return sim, job

    sched = dream_full()
    sim, job = fresh_job(slack=1e-5)          # hopeless deadline
    sched._maybe_switch_variant(sim, job, t=0.0)
    assert "@" in job.graph_name              # switched to a lighter subnet

    sim, job = fresh_job(slack=60.0)          # ample slack
    sched._maybe_switch_variant(sim, job, t=0.0)
    assert "@" not in job.graph_name          # kept the original


def test_supernet_switching_engages_under_heavy_load():
    r = run_sim(build_scenario("AR_Social", 0.99), "4K_1OS2WS", dream_full,
                duration_s=4.0)
    lite = sum(v for k, v in r.variant_counts.items() if "@" in k)
    assert lite > 0


def test_static_worse_than_dynamic_on_dynamic_workload():
    """Figure 2's claim on at least the aggregate."""
    scn = build_scenario("AR_Call", 0.5)
    static = run_sim(scn, "4K_1WS2OS", StaticFCFSScheduler, duration_s=3.0)
    dyn = run_sim(scn, "4K_1WS2OS", FCFSScheduler, duration_s=3.0)
    assert dyn.dlv_rate <= static.dlv_rate + 0.05


def test_adaptivity_search_converges():
    """Offline (alpha,beta) search reaches a cost <= its starting point."""
    calls = []

    def ev(a, b):
        c = (a - 0.7) ** 2 + (b - 1.3) ** 2 + 0.05
        calls.append(c)
        return c

    # init within the search's travel budget (radius 0.5 shrinking by 0.5
    # bounds total center travel; far corners are reached only via the
    # random distant samples — matching the paper's near-restart usage)
    trace = optimize_params(ev, init=(1.2, 1.0), seed=0)
    (pa, pb), best = trace.best
    assert best <= ev(1.2, 1.0)
    assert best < 0.05 + 0.3 ** 2   # inside the optimum's basin


def test_cost_model_dataflow_affinity():
    """WS prefers channel-deep FC; OS prefers depthwise/spatial ops."""
    from repro.core.costmodel import layer_latency_s
    from repro.core.types import Accelerator
    ws = Accelerator("ws", 2048, Dataflow.WS)
    os_ = Accelerator("os", 2048, Dataflow.OS)
    # compute-bound shapes (a 1-token FC is DRAM-bound on every dataflow,
    # so the affinity only shows with enough arithmetic intensity)
    gemm = Layer("gemm", OpType.GEMM, K=1024, C=1024, Y=256)
    dw = Layer("dw", OpType.DWCONV, C=512, R=3, S=3, Y=64, X=64)
    assert layer_latency_s(gemm, ws) < layer_latency_s(gemm, os_)
    assert layer_latency_s(dw, os_) < layer_latency_s(dw, ws)


def test_zoo_models_have_layers():
    for name, builder in zoo.ZOO_BUILDERS.items():
        g = builder()
        assert len(g.layers) > 0, name
        assert g.macs > 0, name
