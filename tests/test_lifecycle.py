"""Stream lifecycle (depart/rejoin) semantics, contention-aware transfer
links, and the head-to-tail pipeline-latency metric."""
import math

import pytest

from repro.cluster import (CascadeFuzz, ContendedLinks,
                           FleetScenarioBuilder, FleetSimulator, FuzzSpec,
                           LifecycleFuzz, TransferModel)
from repro.cluster import trace as ftrace
from repro.core.uxcost import (ModelWindowStats, WindowStats,
                               overall_pipeline_latency)
from repro.scenarios import ScenarioError

SMALL_SYSTEMS = ("4K_1WS2OS", "8K_2WS", "4K_2OS", "8K_1OS2WS")


def lifecycle_fleet(seed=2, n_nodes=4, n_streams=16, dur=1.5, churn=False,
                    depart_frac=0.5, rejoin_frac=0.5):
    b = FleetScenarioBuilder("test_lifecycle")
    nids = [b.node(SMALL_SYSTEMS[i % len(SMALL_SYSTEMS)])
            for i in range(n_nodes)]
    if churn:
        b.node("8K_1WS2OS", at=0.4 * dur)
        b.node_drain(nids[1], at=0.5 * dur)
    b.fuzz_streams(FuzzSpec(
        n_streams=n_streams, seed=seed, t0=0.0, t1=0.4 * dur,
        fps_scale=0.3,
        lifecycle=LifecycleFuzz(depart_frac=depart_frac,
                                rejoin_frac=rejoin_frac,
                                t0=0.45 * dur, t1=0.9 * dur)))
    return b.build()


def one_stream_fleet(fps=60.0, depart_at=0.8, rejoin_at=None, dur=1.5,
                     extra_stream=True):
    """One (or two) explicit streams on one node, with a scripted depart."""
    b = FleetScenarioBuilder("one_stream")
    b.node("4K_1WS2OS")
    sid = b.add_stream([{"model": {"builder": "kws_res8", "name": "kws",
                                   "kwargs": {}}, "fps": fps}], at=0.0)
    if extra_stream:
        b.add_stream([{"model": {"builder": "ed_tcn", "name": "tcn",
                                 "kwargs": {}}, "fps": 15.0}], at=0.0)
    b.depart(sid, at=depart_at)
    if rejoin_at is not None:
        b.rejoin(sid, at=rejoin_at)
    return b.build(), sid


# ---------------------------------------------------------------------------
# builder validation + fuzzer lifecycle draws
# ---------------------------------------------------------------------------

def test_builder_rejects_bad_lifecycle():
    b = FleetScenarioBuilder("bad")
    b.node("4K_1WS2OS")
    with pytest.raises(ScenarioError):
        b.depart(0, at=1.0)                    # unknown stream id
    sid = b.add_stream([{"model": {"builder": "kws_res8", "name": "kws",
                                   "kwargs": {}}, "fps": 10.0}], at=0.5)
    b.depart(sid, at=0.2)                      # precedes the arrival
    with pytest.raises(ScenarioError):
        b.build()

    b2 = FleetScenarioBuilder("bad2")
    b2.node("4K_1WS2OS")
    s2 = b2.add_stream([{"model": {"builder": "kws_res8", "name": "kws",
                                   "kwargs": {}}, "fps": 10.0}], at=0.0)
    b2.depart(s2, at=0.5).depart(s2, at=0.8)   # double depart, no rejoin
    with pytest.raises(ScenarioError):
        b2.build()

    b3 = FleetScenarioBuilder("bad3")
    b3.node("4K_1WS2OS")
    s3 = b3.add_stream([{"model": {"builder": "kws_res8", "name": "kws",
                                   "kwargs": {}}, "fps": 10.0}], at=0.0)
    b3.rejoin(s3, at=0.5)                      # rejoin without depart
    with pytest.raises(ScenarioError):
        b3.build()

    # depart -> rejoin -> depart is a legal lifecycle
    b4 = FleetScenarioBuilder("ok")
    b4.node("4K_1WS2OS")
    s4 = b4.add_stream([{"model": {"builder": "kws_res8", "name": "kws",
                                   "kwargs": {}}, "fps": 10.0}], at=0.0)
    b4.depart(s4, at=0.3).rejoin(s4, at=0.6).depart(s4, at=0.9)
    b4.build()


def test_fuzz_lifecycle_draws_are_rng_compatible():
    """depart_frac>0 must not perturb the arrival/pipeline draws, and the
    lifecycle draws themselves must be deterministic per seed."""
    def events(depart_frac):
        b = FleetScenarioBuilder("fz")
        b.node("4K_1WS2OS")
        b.fuzz_streams(FuzzSpec(
            n_streams=12, seed=7, t0=0.0, t1=0.5, fps_scale=0.3,
            lifecycle=LifecycleFuzz(depart_frac=depart_frac,
                                    rejoin_frac=0.5)))
        return b.build().events

    plain = [e.to_config() for e in events(0.0)]
    churned = [e.to_config() for e in events(0.5)]
    churned2 = [e.to_config() for e in events(0.5)]
    assert churned == churned2                 # deterministic per seed
    assert [e for e in churned if e["kind"] == "stream"] == \
        [e for e in plain if e["kind"] == "stream"]
    departs = [e for e in churned if e["kind"] == "depart"]
    assert len(departs) == 6                   # round(0.5 * 12)
    assert all(e["kind"] != "depart" for e in plain)


# ---------------------------------------------------------------------------
# departure semantics
# ---------------------------------------------------------------------------

def test_departure_releases_load_and_rearms_probe():
    """After a depart, the hosting node holds no placement for the stream,
    its offered load drops to the survivors', and the eviction re-armed
    the node's (alpha, beta) probe."""
    scn, sid = one_stream_fleet(fps=60.0, depart_at=0.8)
    fs = FleetSimulator(scn, "score", duration_s=1.5, seed=0)
    r = fs.run()
    node = fs.nodes[0]
    assert r.departures == 1 and r.rejoins == 0
    assert sid not in fs.stream_node
    assert sid not in node.placements and len(node.placements) == 1
    # offered load after depart equals the surviving stream's alone
    survivor = fs.streams[1 - sid]
    assert node.offered_s == pytest.approx(
        survivor.cost_on(node).offered_s)
    # two placements + one departure eviction, each re-arming the probe
    assert node.probe_retriggers == 3


def overloaded_fleet(depart=True):
    """Five heavy streams saturate one 3-accelerator node, so the ready
    queue is never empty — a departure then has real backlog to purge."""
    b = FleetScenarioBuilder("overload")
    b.node("4K_1WS2OS")
    sids = [b.add_stream(
        [{"model": {"builder": "ssd_mnv2", "name": f"det{i}",
                    "kwargs": {"res": 640}}, "fps": 60.0}], at=0.0)
        for i in range(5)]
    if depart:
        b.depart(sids[0], at=0.5)
    return b.build(), sids[0]


def test_departure_purges_backlog_without_uxcost_penalty():
    """An overloaded stream departs: its queued frames are discarded
    (jobs_purged > 0) and do NOT count as frames, violations or drops —
    versus the same run without the departure, the departed stream's
    recorded frames shrink and its violations can only go down."""
    scn, sid = overloaded_fleet(depart=True)
    fs = FleetSimulator(scn, "score", duration_s=1.0, seed=0)
    r = fs.run()
    assert r.jobs_purged > 0
    ctrl_scn, _ = overloaded_fleet(depart=False)
    ctrl = FleetSimulator(ctrl_scn, "score", duration_s=1.0, seed=0).run()
    key = f"s{sid}.det0"
    assert r.stats.per_model[key].frames < ctrl.stats.per_model[key].frames
    assert r.stats.per_model[key].violated <= \
        ctrl.stats.per_model[key].violated


def test_split_depart_releases_every_stage(monkeypatch):
    """A split-placed stream's departure evicts and purges *each stage key*
    on its hosting node — not just the head — and the fleet's purge count
    is exactly the sum of the per-stage purges."""
    from repro.cluster.node import FleetNode
    calls = []
    orig = FleetNode.release

    def spy(self, key, t):
        n = orig(self, key, t)
        calls.append((key, n))
        return n

    monkeypatch.setattr(FleetNode, "release", spy)
    b = FleetScenarioBuilder("split_depart")
    for i in range(4):
        b.node(SMALL_SYSTEMS[i])
    sids = b.fuzz_streams(FuzzSpec(
        n_streams=10, seed=3, t0=0.0, t1=0.5, fps_scale=0.25,
        cascade=CascadeFuzz(prob=1.0, max_depth=3, only=True),
        lifecycle=LifecycleFuzz(depart_frac=1.0, t0=0.6, t1=1.2)))
    fs = FleetSimulator(b.build(), "score", duration_s=1.5, seed=3,
                        transfer=TransferModel(), split_stages=True)
    r = fs.run()
    assert r.departures == len(sids)
    by_sid: dict[int, list] = {}
    for key, _ in calls:
        assert isinstance(key, tuple)          # stage keys, never bare sids
        by_sid.setdefault(key[0], []).append(key)
    for sid in sids:
        assert sorted(by_sid[sid]) == [
            (sid, k) for k in range(fs.streams[sid].n_stages)]
    assert r.jobs_purged == sum(n for _, n in calls)


def test_purge_keeps_partial_execution_energy():
    """Departure purges discard queued jobs without counting frames or
    violations — but a job evicted *between* dispatch blocks already
    burned real joules, which stay in the stream's energy accounting
    (energy spent is never un-spent).  Fresh queued jobs contribute
    nothing; running jobs are not purged at all."""
    from repro.core import build_scenario, dream_full
    from repro.core.simulator import Simulator
    scn = build_scenario("AR_Call", 0.5)
    sim = Simulator(scn, "4K_1WS2OS", dream_full(), duration_s=1.0)
    name = sim.specs[0].model.name
    st = sim.window_stats.model(name)
    frames0, energy0 = st.frames, st.energy_j
    # control: purging untouched queued jobs adds no energy
    sim._create_job(0, t=0.0)
    assert sim.purge_model(name) == 1
    assert st.energy_j == energy0 and st.frames == frames0
    # a partially-executed (queued-between-blocks) job keeps its joules
    j = sim._create_job(0, t=0.0)
    j.pos = 1
    j.energy_used = 0.125
    running = sim._create_job(0, t=0.0)
    running.running = True                     # in flight: must survive
    assert sim.purge_model(name) == 1
    assert st.energy_j == energy0 + 0.125
    assert st.frames == frames0 and st.violated == 0
    assert running.jid in sim.jobs


def test_uxcost_windows_close_out_departed_streams():
    """Telemetry windows after a departure report no new frames for the
    departed stream — its UXCost accounting is closed out, not dragged."""
    scn, sid = one_stream_fleet(fps=60.0, depart_at=0.6, dur=1.5)
    fs = FleetSimulator(scn, "score", duration_s=1.5, seed=0,
                        tune_every_s=0.25)
    fs.run()
    wins = fs.telemetry.windows
    assert wins, "tune ticks should have produced telemetry windows"
    pre = [w for w in wins if w.t1 <= 0.6]
    post = [w for w in wins if w.t0 >= 0.85]   # past depart + slack
    assert any(f"s{sid}" in w.stream_uxcost for w in pre)
    assert post and all(f"s{sid}" not in w.stream_uxcost for w in post)
    # the window spanning the departure reports it
    assert sum(w.departures for w in wins) == 1


def test_rejoin_replaces_with_fresh_generation():
    scn, sid = one_stream_fleet(fps=60.0, depart_at=0.6, rejoin_at=0.9)
    fs = FleetSimulator(scn, "score", duration_s=1.5, seed=0)
    r = fs.run()
    assert r.departures == 1 and r.rejoins == 1
    assert fs.stream_node[sid] == 0
    assert fs.gen[sid] == 1                    # generation bumped
    # both residencies collapse to one canonical UXCost entry
    assert f"s{sid}.kws" in r.stats.per_model
    assert not any(name.startswith(f"s{sid}g")
                   for name in r.stats.per_model)
    # the rejoined stream really serves again: more frames than the
    # depart-only run
    gone = FleetSimulator(one_stream_fleet(fps=60.0, depart_at=0.6)[0],
                          "score", duration_s=1.5, seed=0).run()
    assert r.stats.per_model[f"s{sid}.kws"].frames > \
        gone.stats.per_model[f"s{sid}.kws"].frames


def test_lifecycle_rearms_fleet_tuner():
    """Depart and rejoin are workload changes: each re-arms the fleet
    weight tuner (the fleet-level mirror of retrigger_probe)."""
    scn, _ = one_stream_fleet(fps=60.0, depart_at=0.6, rejoin_at=0.9)
    r = FleetSimulator(scn, "tuned_score", duration_s=1.5, seed=0,
                       tune_every_s=0.25).run()
    # control without lifecycle events isolates the membership re-arms
    # (node_join fires one too)
    b = FleetScenarioBuilder("ctl")
    b.node("4K_1WS2OS")
    b.add_stream([{"model": {"builder": "kws_res8", "name": "kws",
                             "kwargs": {}}, "fps": 60.0}], at=0.0)
    b.add_stream([{"model": {"builder": "ed_tcn", "name": "tcn",
                             "kwargs": {}}, "fps": 15.0}], at=0.0)
    ctrl = FleetSimulator(b.build(), "tuned_score", duration_s=1.5,
                          seed=0, tune_every_s=0.25).run()
    assert r.tuner_retriggers == ctrl.tuner_retriggers + 2


def test_lifecycle_trace_replay_bitexact():
    """Lifecycle churn layered on membership churn (drain + migrations):
    record and replay must agree on UXCost, frames, departures, purges
    and pipeline latency — whole-stream and stage-split."""
    tm = TransferModel(link_bandwidth_bytes_s=1.25e9)
    for split in (False, True):
        kw = dict(duration_s=1.5, seed=2, transfer=tm, record=True)
        if split:
            kw["split_stages"] = True
        scn = lifecycle_fleet(churn=True)
        live = FleetSimulator(scn, "score", **kw).run()
        assert live.departures > 0
        replayed = FleetSimulator(
            replay=ftrace.loads(ftrace.dumps(live.trace))).run()
        assert replayed.uxcost == live.uxcost
        assert replayed.frames == live.frames
        assert replayed.departures == live.departures
        assert replayed.rejoins == live.rejoins
        assert replayed.jobs_purged == live.jobs_purged
        assert replayed.pipeline_latency_s == live.pipeline_latency_s
        assert replayed.xfer_energy_j == live.xfer_energy_j
        assert replayed.link_wait_s == live.link_wait_s


def test_depart_events_survive_trace_roundtrip():
    scn, sid = one_stream_fleet(fps=60.0, depart_at=0.6, rejoin_at=0.9)
    fs = FleetSimulator(scn, "score", duration_s=1.5, seed=0, record=True)
    fs.run()
    text = ftrace.dumps(fs.trace)
    t = ftrace.loads(text)
    departs = t.events_of("depart")
    rejoins = t.events_of("rejoin")
    assert len(departs) == 1 and departs[0]["sid"] == sid
    assert "purged" in departs[0]
    assert len(rejoins) == 1 and rejoins[0]["sid"] == sid
    # the rejoin's re-placement is a recorded, generation-bumped decision
    gens = [e["gen"] for e in t.placements if e["sid"] == sid]
    assert gens == [0, 1]


# ---------------------------------------------------------------------------
# contention-aware transfer links
# ---------------------------------------------------------------------------

def test_contended_link_serializes_concurrent_transfers():
    """Two concurrent transfers on one node pair take longer than either
    alone; transfers on different pairs never interact."""
    tm = TransferModel(bandwidth_bytes_s=1e9, base_latency_s=1e-4,
                       link_bandwidth_bytes_s=1e9)
    links = ContendedLinks(tm)
    alone = tm.transfer_s(1e6)                 # idle-link lower bound
    s1, _ = links.transfer(0, 1, 1e6, t=0.0)
    s2, _ = links.transfer(0, 1, 1e6, t=0.0)   # same pair, same instant
    s3, _ = links.transfer(2, 3, 1e6, t=0.0)   # different pair
    assert s1 == pytest.approx(alone)
    assert s2 == pytest.approx(alone + 1e6 / 1e9)  # waited a full service
    assert s2 > s1
    assert s3 == pytest.approx(alone)          # other pairs unaffected
    assert links.n_queued == 1
    assert links.queued_s == pytest.approx(1e6 / 1e9)
    # direction does not matter: (1, 0) shares the (0, 1) wire
    s4, _ = links.transfer(1, 0, 1e6, t=0.0)
    assert s4 > alone
    # once the wire drains, transfers are uncontended again
    s5, _ = links.transfer(0, 1, 1e6, t=10.0)
    assert s5 == pytest.approx(alone)


def test_uncontended_link_matches_pr3_formula_exactly():
    """Infinite link bandwidth degenerates to the historical uncontended
    model bit-exactly, even for overlapping transfers: every realized
    time equals TransferModel.transfer_s and no queueing state is kept."""
    tm = TransferModel()                       # default: inf link bw
    assert not tm.contended
    links = ContendedLinks(tm)
    for _ in range(5):
        s, j = links.transfer(0, 1, 2.5e6, t=0.0)   # all at the same t
        assert s == tm.transfer_s(2.5e6)            # bit-exact, not approx
        assert j == tm.transfer_j(2.5e6)
    assert links.n_queued == 0 and links.queued_s == 0.0


def test_air_gapped_link_still_infinite():
    tm = TransferModel(bandwidth_bytes_s=0.0)
    links = ContendedLinks(tm)
    s, j = links.transfer(0, 1, 1e6, t=0.0)
    assert math.isinf(s)
    assert j == tm.transfer_j(1e6)


def test_transfer_model_config_roundtrip_and_legacy_meta():
    """Uncontended configs serialize without the link field (byte-stable
    with PR-3 trace metas); contended configs round-trip it."""
    legacy = TransferModel()
    assert "link_bandwidth_bytes_s" not in legacy.to_config()
    assert TransferModel.from_config(legacy.to_config()) == legacy
    contended = TransferModel(link_bandwidth_bytes_s=5e8)
    cfg = contended.to_config()
    assert cfg["link_bandwidth_bytes_s"] == 5e8
    assert TransferModel.from_config(cfg) == contended
    # the effective wire rate is capped by the shared link capacity
    assert contended.wire_bandwidth_bytes_s == 5e8
    assert contended.transfer_s(1e6) > legacy.transfer_s(1e6)


def test_uncontended_fleet_migrations_pay_formula_times():
    """With the default (uncontended) model, every recorded migration's
    xfer_s equals the closed-form transfer_s of the moved state — the
    PR-3 degeneracy at fleet level."""
    tm = TransferModel()
    scn = lifecycle_fleet(churn=True, depart_frac=0.0)
    fs = FleetSimulator(scn, "score", duration_s=1.5, seed=2, transfer=tm,
                        record=True)
    r = fs.run()
    migrations = fs.trace.migrations
    assert r.migrations > 0 and len(migrations) == r.migrations
    for ev in migrations:
        sv = fs.streams[ev["sid"]]
        total = sum(sv.state_bytes(k) for k in range(sv.n_stages))
        assert ev["xfer_s"] == tm.transfer_s(total)
    assert r.link_queued == 0 and r.link_wait_s == 0.0


def test_contended_drain_wave_queues_on_links():
    """A drain migrates several streams at one instant: under a finite
    shared link some transfers queue, and the realized delays exceed the
    uncontended ones (same scenario, same placements at the drain)."""
    scn = lifecycle_fleet(seed=4, n_nodes=2, n_streams=10, churn=False,
                          depart_frac=0.0)
    # rebuild with an explicit drain onto a single destination pair
    b = FleetScenarioBuilder("drain_wave")
    b.node("4K_1WS2OS")
    b.node("8K_2WS")
    for e in scn.events:
        if e.kind == "stream":
            b.add_stream(e.payload["entries"], at=e.t)
    b.node_drain(0, at=0.75)
    scn2 = b.build()
    slow = TransferModel(link_bandwidth_bytes_s=2e8)
    r = FleetSimulator(scn2, "score", duration_s=1.5, seed=4,
                       transfer=slow).run()
    assert r.migrations > 1                    # a real wave
    assert r.link_queued >= 1                  # someone waited for the wire
    assert r.link_wait_s > 0.0


# ---------------------------------------------------------------------------
# head-to-tail pipeline latency
# ---------------------------------------------------------------------------

def test_pipeline_latency_stats_merge():
    a = WindowStats()
    a.per_model["m"] = ModelWindowStats(frames=2, pipe_frames=2,
                                        pipe_latency_s=0.4)
    b = WindowStats()
    b.per_model["m"] = ModelWindowStats(frames=1, pipe_frames=1,
                                        pipe_latency_s=0.1)
    a.merge(b)
    assert a.per_model["m"].pipe_frames == 3
    assert a.per_model["m"].pipe_latency_s == pytest.approx(0.5)
    assert overall_pipeline_latency(a) == pytest.approx(0.5 / 3)
    assert overall_pipeline_latency(WindowStats()) == 0.0


def test_pipeline_latency_single_node_cascade():
    """Tail completions record head-arrival -> tail-completion: for a
    trigger_prob=1 cascade the tail's pipeline latency must cover both
    stages (strictly larger than the tail model's own mean latency)."""
    b = FleetScenarioBuilder("pipe")
    b.node("4K_1WS2OS")
    b.add_stream([
        {"model": {"builder": "ssd_mnv2", "name": "det",
                   "kwargs": {"res": 512}}, "fps": 20.0},
        {"model": {"builder": "handpose", "name": "pose",
                   "kwargs": {"res": 288}}, "fps": 20.0,
         "depends_on": "det", "trigger_prob": 1.0},
    ], at=0.0)
    fs = FleetSimulator(b.build(), "score", duration_s=1.5, seed=0)
    r = fs.run()
    st = r.stats.per_model["s0.pose"]
    assert st.pipe_frames > 0
    # only the tail records pipeline completions
    assert r.stats.per_model["s0.det"].pipe_frames == 0
    mean = st.pipe_latency_s / st.pipe_frames
    tail_only = fs.streams[0].stage_cost_on(fs.nodes[0], 1).iso_s
    assert mean > tail_only                    # covers head + tail stages
    assert r.pipeline_latency_s == overall_pipeline_latency(r.stats)


def test_pipeline_latency_includes_wire_time():
    """Replaying a stage-split trace with a slower link (meta-edited)
    keeps placements identical but lengthens head-to-tail latency: the
    wire time is part of the metric."""
    b = FleetScenarioBuilder("wire")
    for s in ("4K_2WS", "8K_2OS", "4K_2OS", "8K_2WS"):
        b.node(s)
    b.fuzz_streams(FuzzSpec(
        n_streams=8, seed=3, t0=0.0, t1=0.5, fps_scale=0.25,
        cascade=CascadeFuzz(prob=1.0, max_depth=3, only=True)))
    scn = b.build()
    live = FleetSimulator(scn, "score", duration_s=1.5, seed=3,
                          transfer=TransferModel(), split_stages=True,
                          record=True).run()
    assert live.trigger_transfers > 0
    assert live.pipe_frames > 0
    fast = FleetSimulator(
        replay=ftrace.loads(ftrace.dumps(live.trace))).run()
    assert fast.pipeline_latency_s == live.pipeline_latency_s
    slow_trace = ftrace.loads(ftrace.dumps(live.trace))
    slow_trace.meta["transfer"]["bandwidth_bytes_s"] = 2e7   # 62x slower
    slow = FleetSimulator(replay=slow_trace).run()
    assert slow.pipeline_latency_s > live.pipeline_latency_s
