"""Fleet subsystem: step/peek core API, sharding builder, router policies,
elastic membership, fleet-trace replay determinism, CostTable memoization,
stage-split cascade placement and migration/transfer cost accounting."""
import numpy as np
import pytest

from repro.cluster import (CascadeFuzz, FleetScenarioBuilder,
                           FleetSimulator, FuzzSpec, NodeTelemetry,
                           RoundRobinRouter, TransferModel,
                           canonical_stream_model, make_policy,
                           run_fleet, split_pipelines)
from repro.cluster import trace as ftrace
from repro.core import build_scenario, dream_full
from repro.core.costmodel import (build_cost_table, clear_table_cache,
                                  table_cache_info)
from repro.core.scheduler import AdaptivityState, DreamScheduler
from repro.core.simulator import Simulator
from repro.core.types import SYSTEMS
from repro.core.zoo import ZOO_BUILDERS
from repro.scenarios import ScenarioError, registry
from repro.scenarios import trace as strace

SMALL_SYSTEMS = ("4K_1WS2OS", "8K_2WS", "4K_2OS", "8K_1OS2WS")


def small_fleet(seed=2, n_streams=24, churn=False, dur=1.5):
    b = FleetScenarioBuilder("test_fleet")
    nids = [b.node(s) for s in SMALL_SYSTEMS]
    if churn:
        b.node("8K_1WS2OS", at=0.4 * dur)
        b.node_drain(nids[2], at=0.5 * dur)
        b.node_leave(nids[1], at=0.7 * dur)
    b.fuzz_streams(FuzzSpec(n_streams=n_streams, seed=seed,
                            t0=0.0, t1=0.5 * dur, fps_scale=0.25))
    return b.build()


# ---------------------------------------------------------------------------
# core step/peek API
# ---------------------------------------------------------------------------

def test_step_peek_matches_run():
    """Driving a Simulator through start/step_until/finalize reproduces
    run() exactly — the contract the fleet clock depends on."""
    scn = build_scenario("AR_Call", 0.5)
    ref = Simulator(scn, "4K_1WS2OS", dream_full(seed=0),
                    duration_s=1.5, seed=0).run()
    sim = Simulator(scn, "4K_1WS2OS", dream_full(seed=0),
                    duration_s=1.5, seed=0)
    sim.start()
    assert sim.peek_t() is not None
    for lim in np.arange(0.1, 1.6, 0.1):    # interleaved advancement
        sim.step_until(float(lim))
    r = sim.finalize()
    assert r.uxcost == ref.uxcost
    assert r.frames == ref.frames
    assert r.drops == ref.drops


def test_start_twice_raises():
    scn = build_scenario("AR_Call", 0.5)
    sim = Simulator(scn, "4K_1WS2OS", dream_full(seed=0),
                    duration_s=0.5, seed=0)
    sim.start()
    with pytest.raises(RuntimeError):
        sim.start()


# ---------------------------------------------------------------------------
# fleet scenario builder
# ---------------------------------------------------------------------------

def test_split_pipelines_shards_registry_scenario():
    pipes = split_pipelines(registry.get("VR_Gaming"))
    heads = [p[0]["model"]["name"] for p in pipes]
    assert heads == ["gaze_fbnet_c", "hand_det_ssd", "ctx_ofa", "kws_res8"]
    by_head = {p[0]["model"]["name"]: p for p in pipes}
    assert [e["model"]["name"] for e in by_head["hand_det_ssd"]] == \
        ["hand_det_ssd", "pose_handpose"]
    assert by_head["hand_det_ssd"][1]["depends_on"] == "hand_det_ssd"


def test_fleet_builder_validates():
    with pytest.raises(ScenarioError):
        FleetScenarioBuilder("no_nodes").build()
    b = FleetScenarioBuilder("no_streams")
    b.node("4K_2WS")
    with pytest.raises(ScenarioError):
        b.build()
    with pytest.raises(ScenarioError):
        b.node_leave(99, at=1.0)
    with pytest.raises(ScenarioError):
        b.add_stream([])                      # empty pipeline
    cfg = registry.get("AR_Call").entries[1].to_config()
    cfg["model"]["name"] = "translate_gnmt"
    with pytest.raises(ScenarioError):        # child-first pipeline
        b.add_stream([cfg])
    late = FleetScenarioBuilder("early_leave")
    late.node("4K_2WS")
    nid = late.node("8K_2OS", at=1.0)
    late.node_leave(nid, at=0.5)              # leave precedes the join
    late.fuzz_streams(FuzzSpec(n_streams=2, seed=0))
    with pytest.raises(ScenarioError):
        late.build()


def test_fleet_scenario_roundtrips_config():
    fscn = small_fleet()
    from repro.cluster import FleetScenario
    rebuilt = FleetScenario.from_config(fscn.to_config())
    assert rebuilt == fscn


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------

def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        make_policy("nope")


def test_round_robin_spreads_streams():
    fscn = small_fleet()
    fs = FleetSimulator(fscn, RoundRobinRouter(), duration_s=1.0, seed=2)
    r = fs.run()
    assert r.frames > 0
    counts = [pn["streams"] for pn in r.per_node]
    assert all(c > 0 for c in counts)
    assert max(counts) - min(counts) <= 1    # count-balanced by definition


def test_score_beats_round_robin_on_fleet_uxcost():
    """The DREAM-Fleet acceptance bar: score-driven global routing lowers
    fleet UXCost vs round-robin on a capacity-heterogeneous fleet."""
    fscn = small_fleet(seed=2, n_streams=28)
    rr = run_fleet(fscn, "round_robin", duration_s=1.5, seed=2)
    sc = run_fleet(fscn, "score", duration_s=1.5, seed=2)
    assert sc.uxcost < rr.uxcost
    assert sc.frames > 0 and rr.frames > 0


def test_node_telemetry_shape():
    fscn = small_fleet(n_streams=8)
    fs = FleetSimulator(fscn, "least_loaded", duration_s=0.8, seed=0)
    fs.run()
    tel = fs.nodes[0].telemetry()
    assert isinstance(tel, NodeTelemetry)
    assert tel.n_accs == len(SYSTEMS[SMALL_SYSTEMS[0]])
    assert tel.offered_util >= 0.0
    assert 0.0 <= tel.utilization <= 1.0


# ---------------------------------------------------------------------------
# elastic membership
# ---------------------------------------------------------------------------

def test_elastic_membership_migrates_and_retriggers():
    fscn = small_fleet(churn=True)
    r = run_fleet(fscn, "score", duration_s=1.5, seed=2)
    assert r.n_nodes == 5                    # 4 initial + mid-run join
    assert r.migrations > 0                  # drain + leave forced moves
    assert r.probe_retriggers > 0            # (alpha, beta) probe re-armed
    by_node = {pn["node"]: pn for pn in r.per_node}
    assert by_node[1]["alive"] is False      # left abruptly
    assert by_node[2]["draining"] is True    # drained gracefully
    assert by_node[2]["streams"] == 0        # everything migrated off
    assert by_node[4]["frames"] > 0          # the joiner took real work


def test_adaptivity_retrigger():
    st = AdaptivityState(center=np.array([1.0, 1.0]))
    st.probing = False
    st.radius = 0.01
    st.retrigger()
    assert st.probing and st.radius >= 0.4 and not st.candidates
    sched = DreamScheduler(adaptivity=True)
    sched.retrigger_probe()                  # smoke: no-throw, re-arms
    assert sched.adapt.probing


# ---------------------------------------------------------------------------
# fleet trace record/replay
# ---------------------------------------------------------------------------

def test_fleet_trace_replay_bitexact():
    fscn = small_fleet(churn=True)
    live = FleetSimulator(fscn, "score", duration_s=1.5, seed=2,
                          record=True, rebalance_every_s=0.5).run()
    text = ftrace.dumps(live.trace)
    assert text == ftrace.dumps(ftrace.loads(text))   # bytes-stable JSONL
    rep = FleetSimulator(replay=ftrace.loads(text)).run()
    assert rep.uxcost == live.uxcost
    assert rep.frames == live.frames
    assert rep.drops == live.drops
    assert rep.migrations == live.migrations


def test_fleet_trace_rejects_foreign_formats():
    sim_trace = strace.Trace(meta={"version": 1}, events=[])
    with pytest.raises(ValueError):
        ftrace.loads(strace.dumps(sim_trace))         # not a fleet trace
    fscn = small_fleet(n_streams=4)
    live = FleetSimulator(fscn, "score", duration_s=0.6, seed=0,
                          record=True).run()
    with pytest.raises(ValueError):                   # fleet kinds are not
        strace.loads(ftrace.dumps(live.trace))        # simulator kinds


# ---------------------------------------------------------------------------
# stage-split cascade placement + transfer cost accounting
# ---------------------------------------------------------------------------

def cascade_fleet(seed=3, n_streams=10, dur=1.5, churn=False):
    b = FleetScenarioBuilder("test_cascades")
    nids = [b.node(s) for s in ("4K_2WS", "8K_2OS", "4K_2OS", "8K_2WS")]
    if churn:
        b.node("8K_1WS2OS", at=0.4 * dur)
        b.node_drain(nids[0], at=0.5 * dur)
    b.fuzz_streams(FuzzSpec(
        n_streams=n_streams, seed=seed, t0=0.0, t1=0.5 * dur,
        fps_scale=0.25, cascade=CascadeFuzz(prob=1.0, max_depth=3,
                                            only=True)))
    return b.build()


def test_canonical_collapses_stage_and_generation_prefixes():
    assert canonical_stream_model("s12.det") == "s12.det"
    assert canonical_stream_model("s12g2.det") == "s12.det"
    assert canonical_stream_model("s12t1.det") == "s12.det"
    assert canonical_stream_model("s12t1g3.det") == "s12.det"


def test_split_requires_transfer_model():
    with pytest.raises(ValueError):
        FleetSimulator(cascade_fleet(), "score", duration_s=1.0,
                       split_stages=True)


def test_stage_split_places_and_triggers_across_nodes():
    """The tentpole: stages of one cascade land on different nodes, the
    cross-node triggers actually run the children, and the transfer bill
    (energy into the UXCost merge) is nonzero."""
    fs = FleetSimulator(cascade_fleet(), "score", duration_s=1.5, seed=3,
                        transfer=TransferModel(), split_stages=True)
    r = fs.run()
    assert r.split
    split_sids = [sid for sid, sv in fs.streams.items()
                  if len({fs.stage_node[(sid, k)]
                          for k in range(sv.n_stages)}) > 1]
    assert split_sids                        # at least one pipeline split
    assert r.trigger_transfers > 0           # cross-node cascades fired
    assert r.xfer_energy_j > 0.0
    # children of split streams really execute (cross-node triggers landed):
    # individual low-probability children may finish zero frames in a short
    # run, but across all split pipelines the cascades must have flowed
    child_frames = sum(
        r.stats.per_model[f"s{sid}." + fs.streams[sid].stage_base(k)].frames
        for sid in split_sids
        for k in range(1, fs.streams[sid].n_stages)
        if f"s{sid}." + fs.streams[sid].stage_base(k) in r.stats.per_model)
    assert child_frames > 0


def test_zero_bandwidth_degenerates_to_whole_pipeline():
    """bw=0 means no usable inter-node link: every stage co-locates with
    its head (whole-pipeline placement) and no trigger ever crosses —
    including through drain-driven migrations, which must neither split a
    stream nor dump every moved head onto the lowest node id."""
    for churn in (False, True):
        fs = FleetSimulator(cascade_fleet(churn=churn), "score",
                            duration_s=1.5, seed=3,
                            transfer=TransferModel(bandwidth_bytes_s=0.0),
                            split_stages=True)
        r = fs.run()
        for sid, sv in fs.streams.items():
            nodes = {fs.stage_node[(sid, k)] for k in range(sv.n_stages)}
            assert len(nodes) == 1
        assert r.trigger_transfers == 0
        if churn:
            assert r.migrations > 0
            hosts = {fs.stage_node[(sid, 0)] for sid in fs.streams}
            assert len(hosts) > 1        # drained streams spread, not piled


def test_score_whole_control_never_splits_through_churn():
    """The whole-pipeline control arm must keep every stream co-located
    even across drain migrations and rebalance ticks — placement
    granularity is the only variable in the whole-vs-split comparison."""
    fs = FleetSimulator(cascade_fleet(churn=True), "score_whole",
                        duration_s=1.5, seed=3, transfer=TransferModel(),
                        split_stages=True, rebalance_every_s=0.5)
    r = fs.run()
    assert r.migrations > 0              # the drain really moved streams
    for sid, sv in fs.streams.items():
        nodes = {fs.stage_node[(sid, k)] for k in range(sv.n_stages)}
        assert len(nodes) == 1
    assert r.trigger_transfers == 0


def test_drain_charges_transfer_cost_exactly_once_per_stream():
    """A drain mid-run charges each moved stream's state transfer exactly
    once: total charged energy equals bytes-moved x energy-per-byte summed
    over the recorded migrations, nothing more."""
    T = TransferModel()
    fscn = small_fleet(seed=2, n_streams=12, churn=False)
    b_events = list(fscn.events)
    from repro.cluster import FleetEvent, FleetScenario
    b_events.append(FleetEvent(1.0, "node_drain", {"node": 1}))
    fscn = FleetScenario("drain_charge", tuple(sorted(
        b_events, key=lambda e: e.t)))
    fs = FleetSimulator(fscn, "score", duration_s=1.5, seed=2,
                        transfer=T, record=True)
    r = fs.run()
    migrated = r.trace.migrations
    assert migrated                          # the drain moved something
    assert len({m["sid"] for m in migrated}) == len(migrated)  # once each
    expected = sum(
        T.transfer_j(fs.streams[m["sid"]].state_bytes(k))
        for m in migrated
        for k in range(fs.streams[m["sid"]].n_stages))
    assert r.xfer_energy_j == pytest.approx(expected, rel=1e-12)
    # per-model: each moved stage's canonical entry charged exactly once
    for m in migrated:
        sv = fs.streams[m["sid"]]
        for k in range(sv.n_stages):
            name = f"s{m['sid']}." + sv.stage_base(k)
            assert fs.xfer_energy[name] == pytest.approx(
                T.transfer_j(sv.state_bytes(k)), rel=1e-12)


def test_drain_charges_transfer_cost_exactly_once_per_stage():
    """Stage-split churn run: every recorded stage migration carries its
    own charge, and the fleet total is exactly the sum of the records."""
    T = TransferModel()
    fs = FleetSimulator(cascade_fleet(churn=True), "score", duration_s=1.5,
                        seed=3, transfer=T, split_stages=True, record=True)
    r = fs.run()
    migrated = r.trace.migrations
    assert migrated and all("stage" in m for m in migrated)
    assert r.stage_migrations == len(migrated)
    mig_total = sum(m["xfer_j"] for m in migrated)
    trig_total = r.xfer_energy_j - mig_total
    assert trig_total >= 0.0                 # remainder = trigger transfers
    for m in migrated:
        sv = fs.streams[m["sid"]]
        assert m["xfer_j"] == pytest.approx(
            T.transfer_j(sv.state_bytes(m["stage"])), rel=1e-12)


def test_migration_heavy_split_trace_replays_bitexact():
    """Stage-split + churn + rebalance: record, serialize, replay — fleet
    UXCost, frames, migrations and transfer charges all reproduce."""
    live_fs = FleetSimulator(cascade_fleet(churn=True), "score",
                             duration_s=1.5, seed=3,
                             transfer=TransferModel(), split_stages=True,
                             record=True, rebalance_every_s=0.5)
    live = live_fs.run()
    assert live.migrations > 0
    text = ftrace.dumps(live.trace)
    assert text == ftrace.dumps(ftrace.loads(text))   # bytes-stable JSONL
    rep_fs = FleetSimulator(replay=ftrace.loads(text))
    rep = rep_fs.run()
    assert rep.uxcost == live.uxcost
    assert rep.frames == live.frames
    assert rep.drops == live.drops
    assert rep.migrations == live.migrations
    assert rep.trigger_transfers == live.trigger_transfers
    assert rep.xfer_energy_j == live.xfer_energy_j
    assert rep_fs.xfer_energy == live_fs.xfer_energy


# ---------------------------------------------------------------------------
# CostTable memoization
# ---------------------------------------------------------------------------

def test_cost_table_memoized_across_builds():
    import dataclasses
    clear_table_cache()
    accs = SYSTEMS["4K_1WS2OS"]
    g1 = ZOO_BUILDERS["kws_res8"]()
    g2 = ZOO_BUILDERS["kws_res8"]()          # independent, equal graph
    t1 = build_cost_table(g1, accs)
    t2 = build_cost_table(g2, accs)
    assert t1 is t2                          # structural key, same object
    info = table_cache_info()
    assert info["hits"] >= 1 and info["misses"] >= 1
    # a renamed instance (fleet placement namespacing) hits the cache and
    # shares the arrays — only the label differs
    g3 = dataclasses.replace(g1, name="s12.kws")
    t3 = build_cost_table(g3, accs)
    assert t3.model_name == "s12.kws" and t3.lat is t1.lat
    assert table_cache_info()["hits"] == info["hits"] + 1
    t4 = build_cost_table(g1, SYSTEMS["8K_2WS"])
    assert t4 is not t1                      # different system, new table


def test_fleet_rejects_bad_config():
    fscn = small_fleet(n_streams=4)
    with pytest.raises(ValueError):
        FleetSimulator(fscn, "score", duration_s=1.0, rebalance_every_s=0.0)
    live = FleetSimulator(fscn, "score", duration_s=0.6, seed=0,
                          record=True).run()
    from repro.core.scheduler import dream_mapscore
    with pytest.raises(ValueError):          # scheduler mismatch vs trace
        FleetSimulator(replay=live.trace,
                       scheduler_factory=lambda s: dream_mapscore(seed=s))
