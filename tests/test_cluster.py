"""Fleet subsystem: step/peek core API, sharding builder, router policies,
elastic membership, fleet-trace replay determinism, CostTable memoization."""
import numpy as np
import pytest

from repro.cluster import (FleetScenarioBuilder, FleetSimulator,
                           NodeTelemetry, RoundRobinRouter, make_policy,
                           run_fleet, split_pipelines)
from repro.cluster import trace as ftrace
from repro.core import build_scenario, dream_full
from repro.core.costmodel import (build_cost_table, clear_table_cache,
                                  table_cache_info)
from repro.core.scheduler import AdaptivityState, DreamScheduler
from repro.core.simulator import Simulator
from repro.core.types import SYSTEMS
from repro.core.zoo import ZOO_BUILDERS
from repro.scenarios import ScenarioError, registry
from repro.scenarios import trace as strace

SMALL_SYSTEMS = ("4K_1WS2OS", "8K_2WS", "4K_2OS", "8K_1OS2WS")


def small_fleet(seed=2, n_streams=24, churn=False, dur=1.5):
    b = FleetScenarioBuilder("test_fleet")
    nids = [b.node(s) for s in SMALL_SYSTEMS]
    if churn:
        b.node("8K_1WS2OS", at=0.4 * dur)
        b.node_drain(nids[2], at=0.5 * dur)
        b.node_leave(nids[1], at=0.7 * dur)
    b.fuzz_streams(n_streams, seed=seed, t0=0.0, t1=0.5 * dur,
                   fps_scale=0.25)
    return b.build()


# ---------------------------------------------------------------------------
# core step/peek API
# ---------------------------------------------------------------------------

def test_step_peek_matches_run():
    """Driving a Simulator through start/step_until/finalize reproduces
    run() exactly — the contract the fleet clock depends on."""
    scn = build_scenario("AR_Call", 0.5)
    ref = Simulator(scn, "4K_1WS2OS", dream_full(seed=0),
                    duration_s=1.5, seed=0).run()
    sim = Simulator(scn, "4K_1WS2OS", dream_full(seed=0),
                    duration_s=1.5, seed=0)
    sim.start()
    assert sim.peek_t() is not None
    for lim in np.arange(0.1, 1.6, 0.1):    # interleaved advancement
        sim.step_until(float(lim))
    r = sim.finalize()
    assert r.uxcost == ref.uxcost
    assert r.frames == ref.frames
    assert r.drops == ref.drops


def test_start_twice_raises():
    scn = build_scenario("AR_Call", 0.5)
    sim = Simulator(scn, "4K_1WS2OS", dream_full(seed=0),
                    duration_s=0.5, seed=0)
    sim.start()
    with pytest.raises(RuntimeError):
        sim.start()


# ---------------------------------------------------------------------------
# fleet scenario builder
# ---------------------------------------------------------------------------

def test_split_pipelines_shards_registry_scenario():
    pipes = split_pipelines(registry.get("VR_Gaming"))
    heads = [p[0]["model"]["name"] for p in pipes]
    assert heads == ["gaze_fbnet_c", "hand_det_ssd", "ctx_ofa", "kws_res8"]
    by_head = {p[0]["model"]["name"]: p for p in pipes}
    assert [e["model"]["name"] for e in by_head["hand_det_ssd"]] == \
        ["hand_det_ssd", "pose_handpose"]
    assert by_head["hand_det_ssd"][1]["depends_on"] == "hand_det_ssd"


def test_fleet_builder_validates():
    with pytest.raises(ScenarioError):
        FleetScenarioBuilder("no_nodes").build()
    b = FleetScenarioBuilder("no_streams")
    b.node("4K_2WS")
    with pytest.raises(ScenarioError):
        b.build()
    with pytest.raises(ScenarioError):
        b.node_leave(99, at=1.0)
    with pytest.raises(ScenarioError):
        b.add_stream([])                      # empty pipeline
    cfg = registry.get("AR_Call").entries[1].to_config()
    cfg["model"]["name"] = "translate_gnmt"
    with pytest.raises(ScenarioError):        # child-first pipeline
        b.add_stream([cfg])
    late = FleetScenarioBuilder("early_leave")
    late.node("4K_2WS")
    nid = late.node("8K_2OS", at=1.0)
    late.node_leave(nid, at=0.5)              # leave precedes the join
    late.fuzz_streams(2, seed=0)
    with pytest.raises(ScenarioError):
        late.build()


def test_fleet_scenario_roundtrips_config():
    fscn = small_fleet()
    from repro.cluster import FleetScenario
    rebuilt = FleetScenario.from_config(fscn.to_config())
    assert rebuilt == fscn


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------

def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        make_policy("nope")


def test_round_robin_spreads_streams():
    fscn = small_fleet()
    fs = FleetSimulator(fscn, RoundRobinRouter(), duration_s=1.0, seed=2)
    r = fs.run()
    assert r.frames > 0
    counts = [pn["streams"] for pn in r.per_node]
    assert all(c > 0 for c in counts)
    assert max(counts) - min(counts) <= 1    # count-balanced by definition


def test_score_beats_round_robin_on_fleet_uxcost():
    """The DREAM-Fleet acceptance bar: score-driven global routing lowers
    fleet UXCost vs round-robin on a capacity-heterogeneous fleet."""
    fscn = small_fleet(seed=2, n_streams=28)
    rr = run_fleet(fscn, "round_robin", duration_s=1.5, seed=2)
    sc = run_fleet(fscn, "score", duration_s=1.5, seed=2)
    assert sc.uxcost < rr.uxcost
    assert sc.frames > 0 and rr.frames > 0


def test_node_telemetry_shape():
    fscn = small_fleet(n_streams=8)
    fs = FleetSimulator(fscn, "least_loaded", duration_s=0.8, seed=0)
    fs.run()
    tel = fs.nodes[0].telemetry()
    assert isinstance(tel, NodeTelemetry)
    assert tel.n_accs == len(SYSTEMS[SMALL_SYSTEMS[0]])
    assert tel.offered_util >= 0.0
    assert 0.0 <= tel.utilization <= 1.0


# ---------------------------------------------------------------------------
# elastic membership
# ---------------------------------------------------------------------------

def test_elastic_membership_migrates_and_retriggers():
    fscn = small_fleet(churn=True)
    r = run_fleet(fscn, "score", duration_s=1.5, seed=2)
    assert r.n_nodes == 5                    # 4 initial + mid-run join
    assert r.migrations > 0                  # drain + leave forced moves
    assert r.probe_retriggers > 0            # (alpha, beta) probe re-armed
    by_node = {pn["node"]: pn for pn in r.per_node}
    assert by_node[1]["alive"] is False      # left abruptly
    assert by_node[2]["draining"] is True    # drained gracefully
    assert by_node[2]["streams"] == 0        # everything migrated off
    assert by_node[4]["frames"] > 0          # the joiner took real work


def test_adaptivity_retrigger():
    st = AdaptivityState(center=np.array([1.0, 1.0]))
    st.probing = False
    st.radius = 0.01
    st.retrigger()
    assert st.probing and st.radius >= 0.4 and not st.candidates
    sched = DreamScheduler(adaptivity=True)
    sched.retrigger_probe()                  # smoke: no-throw, re-arms
    assert sched.adapt.probing


# ---------------------------------------------------------------------------
# fleet trace record/replay
# ---------------------------------------------------------------------------

def test_fleet_trace_replay_bitexact():
    fscn = small_fleet(churn=True)
    live = FleetSimulator(fscn, "score", duration_s=1.5, seed=2,
                          record=True, rebalance_every_s=0.5).run()
    text = ftrace.dumps(live.trace)
    assert text == ftrace.dumps(ftrace.loads(text))   # bytes-stable JSONL
    rep = FleetSimulator(replay=ftrace.loads(text)).run()
    assert rep.uxcost == live.uxcost
    assert rep.frames == live.frames
    assert rep.drops == live.drops
    assert rep.migrations == live.migrations


def test_fleet_trace_rejects_foreign_formats():
    sim_trace = strace.Trace(meta={"version": 1}, events=[])
    with pytest.raises(ValueError):
        ftrace.loads(strace.dumps(sim_trace))         # not a fleet trace
    fscn = small_fleet(n_streams=4)
    live = FleetSimulator(fscn, "score", duration_s=0.6, seed=0,
                          record=True).run()
    with pytest.raises(ValueError):                   # fleet kinds are not
        strace.loads(ftrace.dumps(live.trace))        # simulator kinds


# ---------------------------------------------------------------------------
# CostTable memoization
# ---------------------------------------------------------------------------

def test_cost_table_memoized_across_builds():
    import dataclasses
    clear_table_cache()
    accs = SYSTEMS["4K_1WS2OS"]
    g1 = ZOO_BUILDERS["kws_res8"]()
    g2 = ZOO_BUILDERS["kws_res8"]()          # independent, equal graph
    t1 = build_cost_table(g1, accs)
    t2 = build_cost_table(g2, accs)
    assert t1 is t2                          # structural key, same object
    info = table_cache_info()
    assert info["hits"] >= 1 and info["misses"] >= 1
    # a renamed instance (fleet placement namespacing) hits the cache and
    # shares the arrays — only the label differs
    g3 = dataclasses.replace(g1, name="s12.kws")
    t3 = build_cost_table(g3, accs)
    assert t3.model_name == "s12.kws" and t3.lat is t1.lat
    assert table_cache_info()["hits"] == info["hits"] + 1
    t4 = build_cost_table(g1, SYSTEMS["8K_2WS"])
    assert t4 is not t1                      # different system, new table


def test_fleet_rejects_bad_config():
    fscn = small_fleet(n_streams=4)
    with pytest.raises(ValueError):
        FleetSimulator(fscn, "score", duration_s=1.0, rebalance_every_s=0.0)
    live = FleetSimulator(fscn, "score", duration_s=0.6, seed=0,
                          record=True).run()
    from repro.core.scheduler import dream_mapscore
    with pytest.raises(ValueError):          # scheduler mismatch vs trace
        FleetSimulator(replay=live.trace,
                       scheduler_factory=lambda s: dream_mapscore(seed=s))
