"""Serving engine integration: dispatch, cascade, drop, adaptivity."""
import numpy as np
import pytest

from repro.launch.serve import build_handle
from repro.serving import (RequestQueue, ServeRequest, ServingEngine,
                           TraceReplayQueue, VirtualAccelerator)


@pytest.fixture(scope="module")
def small_engine():
    accs = [VirtualAccelerator("big", speed=1.0, power=1.0),
            VirtualAccelerator("small", speed=0.5, power=0.4)]
    eng = ServingEngine(accs, adaptivity=False, frame_drop=True,
                        supernet_switch=True)
    h = build_handle("gemma-2b", "det", layers=1)
    hv = build_handle("gemma-2b", "det@v1", layers=1, d_model=32)
    h.supernet = ("det@v1",)
    eng.register(h, np.zeros((1, 16), np.int32))
    eng.register(hv, np.zeros((1, 16), np.int32))
    return eng


def test_calibration_builds_latency_table(small_engine):
    for acc in small_engine.accs:
        assert ("det", acc.name) in small_engine.lat_table
        assert small_engine.lat_table[("det", acc.name)] > 0
    # slower slice => higher latency entry
    assert (small_engine.lat_table[("det", "small")]
            > small_engine.lat_table[("det", "big")])


def test_mapscore_prefers_fast_slice_when_urgent(small_engine):
    # Pin the calibrated table for this check: lat_table comes from
    # wall-clock measurement, and on a fast (or loaded) machine the
    # measured latency can leave togo/slack too small for the urgency
    # product to dominate the energy term, making the comparison
    # machine-dependent rather than testing the urgency behavior.
    saved = dict(small_engine.lat_table)
    for acc in small_engine.accs:
        small_engine.lat_table[("det", acc.name)] = 0.004 / acc.speed
    try:
        req = ServeRequest(rid=0, model="det",
                           tokens=np.zeros((1, 16), np.int32),
                           arrival=0.0, deadline=0.005)
        scores = {a.name: small_engine._mapscore(req, a, now=0.004)
                  for a in small_engine.accs}
    finally:
        small_engine.lat_table.clear()
        small_engine.lat_table.update(saved)
    assert scores["big"] > scores["small"]


def test_supernet_picks_lighter_variant_when_late(small_engine):
    req = ServeRequest(rid=1, model="det",
                       tokens=np.zeros((1, 16), np.int32),
                       arrival=0.0, deadline=1e-6)     # hopeless deadline
    assert small_engine._pick_variant(req, now=0.0) == "det@v1"
    req2 = ServeRequest(rid=2, model="det",
                        tokens=np.zeros((1, 16), np.int32),
                        arrival=0.0, deadline=60.0)    # relaxed deadline
    assert small_engine._pick_variant(req2, now=0.0) == "det"


def test_end_to_end_run_with_cascade():
    accs = [VirtualAccelerator("a0", speed=1.0, power=1.0),
            VirtualAccelerator("a1", speed=0.5, power=0.5)]
    eng = ServingEngine(accs, adaptivity=True, frame_drop=True,
                        supernet_switch=False)
    parent = build_handle("gemma-2b", "parent", layers=1)
    child = build_handle("gemma-2b", "child", layers=1)
    for h in (parent, child):
        eng.register(h, np.zeros((1, 16), np.int32))
    q = RequestQueue(clock=lambda: 0.0)
    q.add_stream("parent", fps=6, batch=1, seq=16, vocab=64)
    q.add_stream("child", fps=6, batch=1, seq=16, vocab=64,
                 depends_on="parent", trigger_prob=1.0)
    report = eng.run(q, duration_s=2.0)
    assert report.frames > 0
    assert report.per_model.get("parent", {}).get("frames", 0) > 0
    # every completed parent triggers a child (prob 1.0)
    assert report.per_model.get("child", {}).get("frames", 0) > 0
    assert 0.0 <= report.dlv_rate <= 1.0


def test_queue_arrival_process_streams():
    """A Poisson stream drives the queue through the same ArrivalProcess
    objects the simulator consumes; draws are reproducible (crc32 seed)."""
    from repro.scenarios import Poisson

    def emitted():
        q = RequestQueue(clock=lambda: 0.0)
        q.add_stream("m", fps=100, batch=1, seq=4, vocab=8,
                     arrival=Poisson().to_config())
        return [r.arrival for r in q.poll(1.0)]

    ts = emitted()
    assert len(ts) > 10
    assert ts == emitted()                        # deterministic
    gaps = np.diff(ts)
    assert np.std(gaps) > 1e-4                    # genuinely non-periodic


def test_trace_replay_queue_feeds_recorded_arrivals():
    """A simulator-recorded trace replays through the serving queue."""
    from repro.core import build_scenario, dream_full
    from repro.core.simulator import Simulator

    sim = Simulator(build_scenario("AR_Call", 0.5), "4K_1WS2OS",
                    dream_full(), duration_s=1.0, seed=0, record=True)
    sim.run()
    expected = sim.trace.arrivals_by_model()

    q = TraceReplayQueue(clock=lambda: 0.0, trace=sim.trace)
    q.add_stream("kws_res8", fps=15, batch=1, seq=4, vocab=8)
    q.add_stream("translate_gnmt", fps=15, batch=1, seq=4, vocab=8,
                 depends_on="kws_res8", trigger_prob=1.0)
    out = q.poll(1.0)
    assert [r.arrival for r in out] == expected["kws_res8"]
    assert all(r.model == "kws_res8" for r in out)
    assert q.poll(1.0) == []                      # queue drains exactly once
    # dependents stay live (cascade-triggered, not replayed)
    assert len(q.trigger_dependents("kws_res8", now=0.5)) == 1


def test_request_queue_copies_arrival_instances():
    """Stateful arrival processes must never be shared between streams
    (same contract as Simulator._materialize_arrival)."""
    from repro.scenarios.arrivals import BurstyOnOff
    from repro.serving.engine import RequestQueue
    shared = BurstyOnOff(on_s=0.3, off_s=0.3, burst_factor=2.0)
    q = RequestQueue(clock=lambda: 0.0)
    q.add_stream("a", fps=10, batch=1, seq=8, vocab=16, arrival=shared)
    q.add_stream("b", fps=10, batch=1, seq=8, vocab=16, arrival=shared)
    assert q.streams["a"]["arrival"] is not q.streams["b"]["arrival"]
    assert q.streams["a"]["arrival"] is not shared
