"""Launch-layer units that don't need the 512-device dry-run environment."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_applicable, get_config
from repro.launch.dryrun import _shape_bytes, collective_bytes, model_flops
from repro.launch.mesh import rules_for_mesh


def test_shape_bytes_parses_hlo_types():
    assert _shape_bytes("bf16[256,4096]{1,0}") == 256 * 4096 * 2
    assert _shape_bytes("f32[8]{0}") == 32
    assert _shape_bytes("pred[]") == 1
    assert _shape_bytes("(bf16[4,4]{1,0}, f32[2]{0})") == 32 + 8


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[8,32768,8,128]{3,2,1,0} all-gather(bf16[8,2048,8,128]{3,2,1,0} %p), replica_groups={}
  %ar = f32[16,16]{1,0} all-reduce(f32[16,16]{1,0} %q), to_apply=%add
  %rs.1 = f32[4,16]{1,0} reduce-scatter(f32[16,16]{1,0} %r), dimensions={0}
  %cp = u32[2]{0} collective-permute(u32[2]{0} %s), source_target_pairs={{0,1}}
  %noise = f32[128,128]{1,0} fusion(f32[128,128]{1,0} %t), kind=kLoop
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 32768 * 8 * 128 * 2
    assert out["all-reduce"] == 16 * 16 * 4
    assert out["reduce-scatter"] == 4 * 16 * 4
    assert out["collective-permute"] == 8
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_cell_applicability_matrix():
    """7 long_500k skips (pure full-attention), 33 runnable cells."""
    runnable = sum(
        1 for a in ARCH_IDS for s in SHAPES
        if cell_applicable(get_config(a), s))
    assert runnable == 33
    for a in ("mamba2-130m", "zamba2-2.7b", "gemma2-2b"):
        assert cell_applicable(get_config(a), "long_500k")
    for a in ("qwen1.5-4b", "minitron-8b", "phi-3-vision-4.2b"):
        assert not cell_applicable(get_config(a), "long_500k")


def test_model_flops_moe_counts_active_only():
    import jax
    from repro.launch.dryrun import params_spec
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    pspec = params_spec(cfg)
    cell = SHAPES["decode_32k"]
    mf = model_flops(cfg, cell, pspec)
    total = sum(float(l.size) for l in jax.tree.leaves(pspec))
    # active params must be well below total (top-2 of 16 experts)
    assert mf < 2.0 * total * cell.global_batch * 0.5


def test_rules_for_mesh_single_vs_multipod():
    class Single:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    class Multi:
        axis_names = ("pod", "data", "model")
        devices = np.empty((2, 16, 16))

    rs = rules_for_mesh(Single())
    rm = rules_for_mesh(Multi())
    assert rs["batch"] == "data" and rs["fsdp"] == "data"
    assert rm["batch"] == ("pod", "data") and rm["fsdp"] == ("pod", "data")


@pytest.mark.parametrize("shape", list(SHAPES))
def test_shape_cells_match_assignment(shape):
    cell = SHAPES[shape]
    expected = {
        "train_4k": (4096, 256, "train"),
        "prefill_32k": (32768, 32, "prefill"),
        "decode_32k": (32768, 128, "decode"),
        "long_500k": (524288, 1, "decode"),
    }[shape]
    assert (cell.seq_len, cell.global_batch, cell.kind) == expected
