#!/usr/bin/env python
"""Record the fuzz_streams RNG fingerprint.

``FleetScenarioBuilder.fuzz_streams`` promises byte-stable populations
for a fixed (seed, kwargs) combination.  This script serializes the
fuzzed fleet events for a grid of legacy call forms and commits a
sha256 per combination; ``tests/test_fuzz_spec.py`` asserts both the
legacy shim and the ``FuzzSpec`` form still reproduce these hashes.

Regenerate (ONLY after an intentional, reviewed fuzzer change):

    PYTHONPATH=src python tests/golden/gen_fuzz_fingerprint.py
"""
from __future__ import annotations

import hashlib
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                os.pardir, os.pardir, "src"))

GOLDEN_DIR = os.path.dirname(os.path.abspath(__file__))

#: name -> legacy fuzz_streams kwargs (n_streams/seed positional)
COMBOS = {
    "plain": dict(n_streams=12, seed=3),
    "scaled_window": dict(n_streams=10, seed=7, t0=0.1, t1=0.8,
                          fps_scale=0.4),
    "cascades": dict(n_streams=8, seed=11, cascade_prob=1.0, max_depth=3,
                     cascades_only=True, max_pipelines=2,
                     deterministic_arrivals=True),
    "lifecycle": dict(n_streams=14, seed=5, depart_frac=0.5,
                      rejoin_frac=0.4, t_depart0=0.4, t_depart1=0.9),
    "tiered_supernet": dict(n_streams=16, seed=9, fps_scale=0.55,
                            tier_mix=(1.0, 2.0, 2.0), supernet_frac=0.5,
                            deterministic_arrivals=True),
}


def scenario_blob(kwargs: dict) -> bytes:
    from repro.cluster import FleetScenarioBuilder
    kw = dict(kwargs)
    b = FleetScenarioBuilder("fuzz_fingerprint")
    b.node("4K_1WS2OS")
    b.fuzz_streams(kw.pop("n_streams"), kw.pop("seed"), **kw)
    scn = b.build()
    events = [(e.t, e.kind, e.payload) for e in scn.events]
    return json.dumps(events, sort_keys=True, default=str).encode()


def main() -> None:
    out = {}
    for name, kwargs in COMBOS.items():
        blob = scenario_blob(kwargs)
        out[name] = {
            "kwargs": {k: (list(v) if isinstance(v, tuple) else v)
                       for k, v in kwargs.items()},
            "sha256": hashlib.sha256(blob).hexdigest(),
            "bytes": len(blob),
        }
        print(f"fuzz_fingerprint: {name:16s} {len(blob):7d} bytes  "
              f"{out[name]['sha256'][:16]}")
    path = os.path.join(GOLDEN_DIR, "fuzz_fingerprint.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"fuzz_fingerprint: manifest -> {path}")


if __name__ == "__main__":
    main()
