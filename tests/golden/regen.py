#!/usr/bin/env python
"""Regenerate the golden fleet-trace corpus.

Each corpus entry is a small recorded fleet run covering one placement /
scheduling dimension; ``tests/test_golden_traces.py`` replays every
trace and requires the digest of the replayed result to match the
manifest EXACTLY.  The corpus pins two contracts at once:

  * determinism — replaying a recorded trace reproduces the run
    bit-for-bit on any machine, forever;
  * representation stability — the trace format and the vectorized
    fast paths must keep producing these exact results (any diff in
    placements, UXCost, pipeline latency or tier accounting changes
    the digest).

Regenerate (ONLY after an intentional, reviewed behavior change):

    PYTHONPATH=src python tests/golden/regen.py
"""
from __future__ import annotations

import hashlib
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                os.pardir, os.pardir, "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

GOLDEN_DIR = os.path.dirname(os.path.abspath(__file__))

#: corpus entries: name -> (scenario kind for tests.test_vectorized_equiv
#: .build_scenario, seed).  Kinds reuse the differential harness's
#: scenario builders so the corpus and the equivalence suite always
#: exercise the same code paths.
CORPUS = {
    "whole": ("whole", 11),
    "stage_split": ("split", 12),
    "slo_overload": ("slo", 13),
    "lifecycle_churn": ("lifecycle_uncontended", 14),
    "contended_links": ("lifecycle", 15),
    "tuned_score": ("tuned", 16),
    "genai_mixed": ("genai", 17),
}


def build(kind: str, seed: int):
    from test_vectorized_equiv import build_scenario
    from repro.cluster import TransferModel
    if kind == "lifecycle_uncontended":
        # lifecycle churn over uncontended (infinite-bandwidth) links:
        # isolates departure/rejoin bookkeeping from link queueing
        fscn, kw = build_scenario("lifecycle", seed)
        kw["transfer"] = TransferModel()
        return fscn, kw
    return build_scenario(kind, seed)


def result_digest(r, fs) -> str:
    """Canonical digest of a fleet result: every float serialized via
    repr (shortest round-trip form — exact), keys sorted."""
    payload = {
        "uxcost": repr(r.uxcost),
        "frames": r.frames,
        "dlv_rate": repr(r.dlv_rate),
        "norm_energy": repr(r.norm_energy),
        "stream_seconds": repr(r.stream_seconds),
        "pipeline_latency_s": repr(r.pipeline_latency_s),
        "pipe_frames": r.pipe_frames,
        "migrations": r.migrations,
        "departures": r.departures,
        "jobs_purged": r.jobs_purged,
        "swaps": r.swaps,
        "rejections": r.rejections,
        "tier_dlv": {str(k): repr(v)
                     for k, v in sorted(r.tier_dlv.items())},
        "weights": ([repr(w) for w in r.weights]
                    if r.weights is not None else None),
        "stream_node": {str(k): v
                        for k, v in sorted(fs.stream_node.items())},
        "stage_node": {f"{k[0]}:{k[1]}": v
                       for k, v in sorted(fs.stage_node.items())},
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def main() -> None:
    from repro.cluster import FleetSimulator
    from repro.cluster import trace as ftrace
    manifest = {}
    for name, (kind, seed) in CORPUS.items():
        fscn, kw = build(kind, seed)
        policy = kw.pop("policy")
        kw["record"] = True
        fs = FleetSimulator(fscn, policy, **kw)
        r = fs.run()
        text = ftrace.dumps(r.trace)
        path = os.path.join(GOLDEN_DIR, f"{name}.trace.json")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "kind": kind,
            "seed": seed,
            "trace_sha256": hashlib.sha256(text.encode()).hexdigest(),
            "result_sha256": result_digest(r, fs),
            "uxcost": r.uxcost,
            "frames": r.frames,
        }
        print(f"golden: {name:16s} {len(text):7d} bytes  "
              f"frames={r.frames:<5d} uxcost={r.uxcost:.4f}")
    mpath = os.path.join(GOLDEN_DIR, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"golden: manifest -> {mpath}")


if __name__ == "__main__":
    main()
