"""Training stack integration: loss decreases, checkpoint/restart recovery,
gradient compression, accumulation equivalence, resharding restore."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import SyntheticLMData
from repro.distributed import (CheckpointManager, CompressionConfig,
                               FaultInjector, SimulatedPreemption)
from repro.training import (OptimConfig, TrainConfig, Trainer,
                            build_train_step, init_train_state)

KEY = jax.random.PRNGKey(0)


def _cfg(arch="qwen1.5-4b", vocab=64):
    return dataclasses.replace(smoke_config(arch), vocab_size=vocab,
                               dtype="float32")


def _data(vocab=64, batch=8, seq=32, seed=1):
    return SyntheticLMData(vocab_size=vocab, seq_len=seq,
                           global_batch=batch, seed=seed)


def test_loss_decreases():
    cfg = _cfg()
    t = Trainer(cfg=cfg,
                tcfg=TrainConfig(optim=OptimConfig(
                    learning_rate=3e-3, warmup_steps=5, total_steps=40)),
                data=iter(_data()), log_every=1000)
    t.init_or_resume(resume="never")
    h = t.run(40)
    assert h[-1]["loss"] < h[0]["loss"] * 0.8


def test_grad_accumulation_matches_full_batch():
    cfg = _cfg()
    batch = next(iter(_data(batch=8)))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    t1 = TrainConfig(optim=OptimConfig(clip_norm=None), accum=1)
    t4 = TrainConfig(optim=OptimConfig(clip_norm=None), accum=4)
    s0 = init_train_state(KEY, cfg, t1)
    s1, m1 = jax.jit(build_train_step(cfg, t1))(s0, batch)
    s4, m4 = jax.jit(build_train_step(cfg, t4))(s0, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_checkpoint_roundtrip(tmp_path):
    cfg = _cfg()
    tcfg = TrainConfig()
    state = init_train_state(KEY, cfg, tcfg)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, state, extra={"note": "x"})
    step, restored, extra = mgr.restore()
    assert step == 7 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.ones((2,)) * s})
    assert mgr.all_steps() == [3, 4]
    # a stray tmp dir never shows up as a checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp-zz"))
    assert mgr.latest_step() == 4


def test_checkpoint_restore_with_sharding(tmp_path):
    """Restore against explicit shardings (the elastic-restart path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(1, tree)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    _, restored, _ = mgr.restore(shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


def test_crash_restart_resumes_trajectory(tmp_path):
    """Preemption at step 12 -> restart -> final state identical to an
    uninterrupted run (checkpoint cadence aligned with the failure)."""
    cfg = _cfg()
    tcfg = TrainConfig(optim=OptimConfig(learning_rate=1e-3,
                                         warmup_steps=2, total_steps=20))

    def mk(data_seed, ckpt, inject):
        return Trainer(cfg=cfg, tcfg=tcfg, data=iter(_data(seed=data_seed)),
                       ckpt_dir=ckpt, ckpt_every=4, log_every=1000,
                       fault_injector=inject)

    # uninterrupted reference: data stream indexed by step is what matters
    ref = mk(1, None, None)
    ref.init_or_resume(resume="never")
    ref_hist = ref.run(20)

    ckpt = str(tmp_path / "run")
    t1 = mk(1, ckpt, FaultInjector(fail_at_steps=(12,)))
    t1.init_or_resume(resume="never")
    with pytest.raises(SimulatedPreemption):
        t1.run(20)
    # restart: resumes from step 12 checkpoint; replay data from there
    t2 = mk(1, ckpt, None)
    t2.init_or_resume(resume="must")
    assert t2.step == 12
    # fast-forward the data iterator to the resumed step
    data = _data(seed=1)
    t2.data = iter(data.batch(s) for s in range(t2.step, 10_000))
    hist2 = t2.run(20)
    np.testing.assert_allclose(hist2[-1]["loss"], ref_hist[-1]["loss"],
                               rtol=1e-5)


def test_gradient_compression_error_feedback():
    """Compressed training stays close to uncompressed (error feedback
    keeps the trajectory unbiased)."""
    cfg = _cfg()
    data = _data()
    base = TrainConfig(optim=OptimConfig(learning_rate=2e-3,
                                         warmup_steps=2, total_steps=30))
    comp = dataclasses.replace(base, compression=CompressionConfig(block=64))
    losses = {}
    for name, tcfg in (("base", base), ("comp", comp)):
        t = Trainer(cfg=cfg, tcfg=tcfg, data=iter(data), log_every=1000)
        t.init_or_resume(resume="never")
        h = t.run(30)
        losses[name] = h[-1]["loss"]
    assert abs(losses["comp"] - losses["base"]) < 0.25 * losses["base"]


def test_straggler_detector_flags_slow_steps():
    from repro.distributed import StragglerDetector
    import time
    det = StragglerDetector(min_samples=4, threshold=2.0)
    for i in range(6):
        det.start()
        time.sleep(0.002)
        det.stop(i)
    det.start()
    time.sleep(0.05)
    assert det.stop(99) is not None
    assert det.events and det.events[0][0] == 99
