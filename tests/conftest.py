"""Shared test fixtures. NOTE: no XLA_FLAGS here — tests run on the single
real CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

try:
    from hypothesis import HealthCheck, settings
except ImportError:
    # minimal environments run without hypothesis; test_properties.py
    # skips itself at collection via pytest.importorskip
    pass
else:
    # jit compiles inside property bodies blow the default 200ms deadline
    settings.register_profile(
        "jax", deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile("jax")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
