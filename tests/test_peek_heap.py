"""Unit tests for the fleet's persistent lazy peek heap.

PR 8 replaced the fleet clock's per-event full node rescan (a latent
O(nodes) cost paid at *every* fleet event, dominating large fleets) with
a persistent lazy min-heap of ``(next_event_time, node_id)`` entries.
The heap's correctness contract is one-sided:

    at every advancement, the heap holds an entry at or before each
    live node's true next-event time (when that event is reachable
    within the node's own horizon).

Late/stale entries are fine — they re-validate on pop; a *missing or
too-late* entry would silently freeze a node.  These tests run an
instrumented simulator that re-checks the invariant (plus heap/index
consistency and the O(nodes) size bound) at every single advancement of
a churning fleet that exercises all three membership transitions:
``node_join`` (mid-run), ``node_drain`` (graceful) and ``node_leave``
(abrupt) — each of which mutates which nodes the heap must track.
"""
from __future__ import annotations

import pytest

from repro.cluster import (CascadeFuzz, FleetScenarioBuilder,
                           FleetSimulator, FuzzSpec, LifecycleFuzz,
                           TransferModel)

SYSTEMS_MIX = ("4K_2WS", "8K_2OS", "4K_1WS2OS", "8K_1OS2WS")


class _InvariantError(AssertionError):
    pass


class _CheckedFleet(FleetSimulator):
    """FleetSimulator that audits the peek heap at every advancement."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.checks = 0
        self.max_heap_len = 0
        self.transitions_seen: set[str] = set()

    def _audit(self, where: str) -> None:
        self.checks += 1
        self.max_heap_len = max(self.max_heap_len, len(self._peek_heap))
        heap_times: dict[int, list[float]] = {}
        for pt, nid in self._peek_heap:
            heap_times.setdefault(nid, []).append(pt)
        # index consistency: every tracked earliest-entry time must
        # correspond to a real heap entry, and nothing earlier may lurk
        # untracked (an untracked-earlier entry would be discarded on
        # pop and could strand the tracked one behind it)
        for nid, tracked in self._peek_at.items():
            times = heap_times.get(nid)
            if not times or tracked not in times:
                raise _InvariantError(
                    f"{where}: _peek_at[{nid}]={tracked} has no matching "
                    "heap entry")
            if min(times) < tracked:
                raise _InvariantError(
                    f"{where}: node {nid} has a heap entry earlier than "
                    f"its tracked earliest {tracked}")
        # the one-sided invariant itself
        for nid, node in self.nodes.items():
            if not node.alive:
                continue
            pt = node.sim.peek_t()
            if pt is None or pt > node.sim.duration_s:
                continue            # nothing reachable to track
            tracked = self._peek_at.get(nid)
            if tracked is None or tracked > pt:
                raise _InvariantError(
                    f"{where}: live node {nid} next event at {pt} but "
                    f"heap tracks {tracked} — node would freeze")

    def _advance_all(self, t):
        self._audit(f"before _advance_all({t})")
        super()._advance_all(t)
        self._audit(f"after _advance_all({t})")

    def _on_node_join(self, t, ev):
        super()._on_node_join(t, ev)
        self.transitions_seen.add("join")
        self._audit(f"after node_join@{t}")

    def _on_node_drain(self, t, ev):
        super()._on_node_drain(t, ev)
        self.transitions_seen.add("drain")
        self._audit(f"after node_drain@{t}")

    def _on_node_leave(self, t, ev):
        super()._on_node_leave(t, ev)
        self.transitions_seen.add("leave")
        self._audit(f"after node_leave@{t}")


def _churn_scenario(seed: int, split: bool = False):
    """4 starting nodes + 1 mid-run join; one drains, one leaves."""
    b = FleetScenarioBuilder(f"peek_heap_{seed}")
    nids = [b.node(SYSTEMS_MIX[i % len(SYSTEMS_MIX)]) for i in range(4)]
    b.node(SYSTEMS_MIX[seed % len(SYSTEMS_MIX)], at=0.3)   # mid-run join
    b.node_drain(nids[0], at=0.45)
    b.node_leave(nids[1], at=0.6)
    if split:
        b.fuzz_streams(FuzzSpec(
            n_streams=8, seed=seed, t0=0.0, t1=0.5, fps_scale=1.0,
            deterministic_arrivals=True,
            cascade=CascadeFuzz(prob=1.0, max_depth=3, only=True)))
    else:
        b.fuzz_streams(FuzzSpec(
            n_streams=16, seed=seed, t0=0.0, t1=0.5, fps_scale=0.25,
            lifecycle=LifecycleFuzz(depart_frac=0.4, rejoin_frac=0.5,
                                    t0=0.35, t1=0.9)))
    return b.build()


@pytest.mark.parametrize("seed", (2, 9))
def test_peek_heap_invariant_across_join_drain_leave(seed):
    fs = _CheckedFleet(_churn_scenario(seed), "score", duration_s=1.0,
                       seed=seed,
                       transfer=TransferModel(link_bandwidth_bytes_s=1.25e9),
                       rebalance_every_s=0.3)
    r = fs.run()
    assert fs.transitions_seen == {"join", "drain", "leave"}
    assert fs.checks > 50          # the audit actually ran, densely
    assert r.frames > 0
    # lazily-discarded stale entries must not accumulate: the heap stays
    # O(nodes), never O(touches) (5 nodes here; generous slack for
    # in-flight superseded entries)
    assert fs.max_heap_len <= 8 * len(fs.nodes)


def test_peek_heap_invariant_split_mode():
    """Stage-split advancement pops the same heap through the global
    event-order interleave — audit that path too."""
    fs = _CheckedFleet(_churn_scenario(5, split=True), "score",
                       duration_s=1.0, seed=5, split_stages=True,
                       transfer=TransferModel())
    r = fs.run()
    assert fs.transitions_seen == {"join", "drain", "leave"}
    assert fs.checks > 30
    assert r.frames > 0
    assert fs.max_heap_len <= 8 * len(fs.nodes)


def test_peek_heap_matches_scan_oracle_under_churn(monkeypatch):
    """The lazy clock and the O(N)-rescan oracle must produce identical
    results on the membership-churn scenario (the transitions are where
    a missed ``_touch`` would diverge first)."""
    def run_once():
        fs = FleetSimulator(
            _churn_scenario(3), "score", duration_s=1.0, seed=3,
            transfer=TransferModel(link_bandwidth_bytes_s=1.25e9),
            rebalance_every_s=0.3)
        r = fs.run()
        return (r.uxcost, r.frames, r.migrations, r.departures,
                r.stream_seconds, dict(fs.stream_node))

    vec = run_once()
    with monkeypatch.context() as m:
        m.setattr(FleetSimulator, "lazy_peek", False)
        ref = run_once()
    assert vec == ref
