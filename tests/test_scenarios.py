"""Scenario engine: builder/registry, arrival processes, phase scripts,
trace record/replay determinism, fuzzer validity."""
import numpy as np
import pytest

from repro.core import build_scenario, dream_full, run_sim
from repro.core.scheduler import DreamScheduler
from repro.core.simulator import Simulator
from repro.scenarios import (BurstyOnOff, Diurnal, ModelEntry, ModelRef,
                             Periodic, PeriodicJitter, PhaseScript, Poisson,
                             ScenarioBuilder, ScenarioError,
                             arrival_from_config, fuzz_phase_script,
                             fuzz_scenario, join, leave, registry, scale_fps,
                             set_fps, set_trigger_prob, signature)
from repro.scenarios import trace as trace_mod
from repro.scenarios.arrivals import legacy_phase

SYSTEM = "4K_1WS2OS"


def stochastic_scenario() -> ScenarioBuilder:
    return (ScenarioBuilder("stochastic")
            .model("kws_res8", fps=15, name="kws", arrival=Poisson())
            .model("gnmt", fps=15, name="mt", depends_on="kws",
                   trigger_prob=0.7)
            .model("ssd_mnv2", fps=30, name="det", kwargs={"res": 512},
                   arrival=PeriodicJitter(jitter=0.2)))


# ---------------------------------------------------------------------------
# registry serves Table 3
# ---------------------------------------------------------------------------

TABLE3_MODELS = {
    "VR_Gaming": ["gaze_fbnet_c", "hand_det_ssd", "pose_handpose",
                  "ctx_ofa", "kws_res8", "translate_gnmt"],
    "AR_Call": ["kws_res8", "translate_gnmt", "ctx_skipnet"],
    "Drone_Outdoor": ["objdet_ssd", "nav_trailnet", "vo_sosnet"],
    "Drone_Indoor": ["objdet_ssd", "nav_rapid_rl", "obst_sosnet",
                     "car_googlenet"],
    "AR_Social": ["depth_focal", "action_ed_tcn", "face_det_ssd",
                  "verif_vggvox", "ctx_ofa"],
}


@pytest.mark.parametrize("name", sorted(TABLE3_MODELS))
def test_registry_serves_table3(name):
    assert name in registry.names()
    scn = build_scenario(name, 0.5)      # core API delegates to the registry
    assert [s.model.name for s in scn.models] == TABLE3_MODELS[name]
    assert registry.build(name, cascade_prob=0.9).name == name


def test_registry_scenarios_serialize():
    cfg = registry.get("AR_Call", cascade_prob=0.7).to_config()
    rebuilt = ScenarioBuilder.from_config(cfg).build()
    assert [s.model.name for s in rebuilt.models] == TABLE3_MODELS["AR_Call"]
    assert rebuilt.models[1].trigger_prob == 0.7


def test_builder_validation():
    with pytest.raises(ScenarioError):
        ScenarioBuilder("empty").build()
    with pytest.raises(ScenarioError):
        (ScenarioBuilder("dup")
         .model("kws_res8", fps=15, name="a")
         .model("kws_res8", fps=15, name="a").build())
    with pytest.raises(ScenarioError):
        (ScenarioBuilder("dangling")
         .model("gnmt", fps=15, name="mt", depends_on="ghost").build())
    with pytest.raises(ScenarioError):
        ScenarioBuilder("badfps").model("kws_res8", fps=0, name="k").build()


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def _collect(proc, period, n=400, seed=0):
    rng = np.random.default_rng(seed)
    t = proc.start(3, period, rng)
    out = [t]
    for _ in range(n - 1):
        t = proc.next_after(t, period, rng)
        out.append(t)
    return np.asarray(out)


def test_periodic_matches_legacy_schedule():
    ts = _collect(Periodic(), 0.1, n=10)
    assert ts[0] == legacy_phase(3, 0.1)
    np.testing.assert_allclose(np.diff(ts), 0.1)


def test_poisson_mean_interval_tracks_period():
    ts = _collect(Poisson(), 0.05, n=2000)
    assert np.mean(np.diff(ts)) == pytest.approx(0.05, rel=0.1)


def test_jitter_bounded_and_near_periodic():
    gaps = np.diff(_collect(PeriodicJitter(jitter=0.2), 0.1))
    assert np.all(gaps >= 0.08 - 1e-12) and np.all(gaps <= 0.12 + 1e-12)
    assert np.mean(gaps) == pytest.approx(0.1, rel=0.05)


def test_bursty_clusters_arrivals():
    gaps = np.diff(_collect(
        BurstyOnOff(on_s=0.3, off_s=0.7, burst_factor=4.0), 0.1, n=1000))
    # burst gaps are ~period/4; off-state gaps are ~off_s — far apart
    assert np.quantile(gaps, 0.25) < 0.05
    assert np.max(gaps) > 0.3


def test_diurnal_rate_varies_over_the_day():
    day = 4.0
    ts = _collect(Diurnal(amplitude=0.9, day_s=day), 0.01, n=4000)
    phase = (ts % day) / day
    peak = np.sum((phase > 0.0) & (phase < 0.5))      # sin > 0 half
    trough = np.sum((phase >= 0.5) & (phase < 1.0))
    assert peak > 1.5 * trough


def test_arrival_config_roundtrip():
    for proc in (Periodic(phase_frac=0.25), PeriodicJitter(jitter=0.3),
                 Poisson(rate_scale=1.5),
                 BurstyOnOff(on_s=0.4, off_s=0.6, burst_factor=3.0),
                 Diurnal(amplitude=0.5, day_s=6.0, phase=0.1)):
        clone = arrival_from_config(proc.to_config())
        assert clone.to_config() == proc.to_config()
    with pytest.raises(ValueError):
        arrival_from_config({"kind": "martian"})


# ---------------------------------------------------------------------------
# trace record / replay
# ---------------------------------------------------------------------------

def test_same_seed_byte_identical_trace(tmp_path):
    def record():
        sim = Simulator(stochastic_scenario().build(), SYSTEM, dream_full(),
                        duration_s=2.0, seed=11, record=True)
        sim.run()
        return sim.trace

    b1, b2 = trace_mod.dumps(record()), trace_mod.dumps(record())
    assert b1 == b2
    p = tmp_path / "t.jsonl"
    trace_mod.save_trace(record(), str(p))
    assert p.read_bytes().decode() == b1


def test_replay_reproduces_live_uxcost(tmp_path):
    script = PhaseScript([(1.0, scale_fps(2.0))])
    sim = Simulator(stochastic_scenario().build(), SYSTEM, dream_full(),
                    duration_s=2.5, seed=7, phase_script=script, record=True)
    live = sim.run()
    path = trace_mod.save_trace(sim.trace, str(tmp_path / "run.jsonl"))

    replayed = Simulator(stochastic_scenario().build(), SYSTEM, dream_full(),
                         duration_s=2.5, seed=7,
                         replay=trace_mod.load_trace(path)).run()
    assert replayed.uxcost == live.uxcost
    assert replayed.frames == live.frames
    assert replayed.drops == live.drops


def test_replay_rejects_mismatched_scenario():
    sim = Simulator(build_scenario("AR_Call"), SYSTEM, dream_full(),
                    duration_s=1.0, seed=0, record=True)
    sim.run()
    with pytest.raises(ValueError):
        Simulator(build_scenario("VR_Gaming"), SYSTEM, dream_full(),
                  duration_s=1.0, seed=0, replay=sim.trace)


def test_replay_and_phase_script_are_exclusive():
    sim = Simulator(build_scenario("AR_Call"), SYSTEM, dream_full(),
                    duration_s=0.5, seed=0, record=True)
    sim.run()
    with pytest.raises(ValueError):
        Simulator(build_scenario("AR_Call"), SYSTEM, dream_full(),
                  duration_s=0.5, seed=0, replay=sim.trace,
                  phase_script=PhaseScript([(0.1, scale_fps(2.0))]))


# ---------------------------------------------------------------------------
# phase scripts
# ---------------------------------------------------------------------------

def test_phase_switch_retriggers_adaptivity_probe():
    """A workload switch must measurably re-open the (alpha, beta) search."""
    def run_one(script):
        sched = DreamScheduler(adaptivity=True, frame_drop=True,
                               supernet=False, seed=0)
        sched.adapt.probing = False        # pretend the search converged
        sched.adapt.candidates = []
        Simulator(build_scenario("AR_Call", 0.5), "8K_2WS", sched,
                  duration_s=4.0, seed=0, phase_script=script).run()
        return sched.adapt.probing

    assert run_one(None) is False          # stable load: stays converged
    assert run_one(PhaseScript([(2.0, scale_fps(8.0))])) is True


def test_phase_join_and_leave():
    entry = ModelEntry(ref=ModelRef("googlenet_car", name="joined_car"),
                       fps=30, arrival=Poisson().to_config())
    script = PhaseScript([(0.8, join(entry)), (0.8, leave("ctx_skipnet"))])
    sim = Simulator(build_scenario("AR_Call", 0.5), SYSTEM, dream_full(),
                    duration_s=2.5, seed=1, phase_script=script, record=True)
    r = sim.run()
    per = {k: v.frames for k, v in r.stats.per_model.items()}
    assert per.get("joined_car", 0) > 0
    # the left model got at most ~0.8s + one stale period of frames
    no_script = run_sim(build_scenario("AR_Call", 0.5), SYSTEM, dream_full,
                        duration_s=2.5, seed=1)
    assert per["ctx_skipnet"] < no_script.stats.per_model["ctx_skipnet"].frames
    # a trace containing join/leave still replays exactly
    replayed = Simulator(build_scenario("AR_Call", 0.5), SYSTEM, dream_full(),
                         duration_s=2.5, seed=1,
                         replay=trace_mod.loads(
                             trace_mod.dumps(sim.trace))).run()
    assert replayed.uxcost == r.uxcost


def test_join_with_stateful_arrival_starts_at_join_time():
    """A joined stream's arrival process is anchored at the join time —
    its internal MMPP clock must not emit arrivals in the past."""
    entry = ModelEntry(ref=ModelRef("googlenet_car", name="joined_car"),
                       fps=30, arrival=BurstyOnOff(
                           on_s=0.3, off_s=0.3, burst_factor=4.0).to_config())
    script = PhaseScript([(1.0, join(entry))])
    sim = Simulator(build_scenario("AR_Call", 0.5), SYSTEM, dream_full(),
                    duration_s=2.5, seed=1, phase_script=script, record=True)
    sim.run()
    joined_ts = [t for t, m in sim.trace.arrivals if m == "joined_car"]
    assert joined_ts and min(joined_ts) >= 1.0


def test_set_fps_and_trigger_prob_mutate_live_specs():
    script = PhaseScript([(0.5, set_trigger_prob("translate_gnmt", 0.0)),
                          (0.5, scale_fps(2.0, models=["kws_res8"]))])
    sim = Simulator(build_scenario("AR_Call", 1.0), SYSTEM, dream_full(),
                    duration_s=2.0, seed=0, phase_script=script)
    sim.run()
    idx = {s.model.name: i for i, s in enumerate(sim.specs)}
    assert sim.specs[idx["translate_gnmt"]].trigger_prob == 0.0
    assert sim.specs[idx["kws_res8"]].fps == 30.0


def test_phase_action_validation():
    with pytest.raises(ValueError):
        set_fps("m", 0.0)
    with pytest.raises(ValueError):
        scale_fps(-1.0)
    with pytest.raises(ValueError):
        set_trigger_prob("m", 1.5)
    # a hand-edited trace/config with a bad value fails inside the run too
    from repro.scenarios import PhaseAction
    bad = PhaseAction("set_fps", {"model": "kws_res8", "fps": -5.0})
    with pytest.raises(ValueError):
        Simulator(build_scenario("AR_Call", 0.5), SYSTEM, dream_full(),
                  duration_s=1.0, seed=0,
                  phase_script=PhaseScript([(0.1, bad)])).run()


def test_shared_arrival_instance_is_copied_per_stream():
    shared = BurstyOnOff(on_s=0.3, off_s=0.3, burst_factor=4.0)
    scn = (ScenarioBuilder("shared")
           .model("kws_res8", fps=15, name="a", arrival=shared)
           .model("fbnet_c", fps=60, name="b", arrival=shared)).build()
    sim = Simulator(scn, SYSTEM, dream_full(), duration_s=0.5, seed=0)
    procs = sim._arrival_procs
    assert procs[0] is not procs[1]
    assert procs[0] is not shared


def test_phase_script_config_roundtrip():
    script = (PhaseScript()
              .at(2.0, scale_fps(3.0))
              .at(1.0, set_trigger_prob("x", 0.9)))
    clone = PhaseScript.from_config(script.to_config())
    assert clone.to_config() == script.to_config()
    assert [t for t, _ in clone] == [1.0, 2.0]        # kept sorted


# ---------------------------------------------------------------------------
# fuzzer
# ---------------------------------------------------------------------------

def test_fuzzer_generates_100_distinct_valid_scenarios():
    sigs = set()
    for seed in range(100):
        b = fuzz_scenario(seed)
        b.validate()                       # raises on an invalid sample
        scn = b.build()
        assert len(scn.models) >= 1
        assert b.to_config() == type(b).from_config(b.to_config()).to_config()
        sigs.add(signature(b))
    assert len(sigs) == 100
    # determinism: same seed, same scenario
    assert signature(fuzz_scenario(42)) == signature(fuzz_scenario(42))


def test_fuzzed_scenario_simulates():
    b = fuzz_scenario(5)
    r = run_sim(b.build(), SYSTEM, dream_full, duration_s=1.5, seed=0,
                phase_script=fuzz_phase_script(5, b, 1.5))
    assert r.frames > 0 and r.uxcost >= 0.0


def test_join_action_validates_spec():
    """Joins arrive via phase scripts / hand-edited traces and bypass the
    builder — the simulator must re-check the hazards itself."""
    def run_with(entry):
        sim = Simulator(build_scenario("AR_Call", 0.5), SYSTEM,
                        DreamScheduler(adaptivity=False), duration_s=0.6,
                        seed=0,
                        phase_script=PhaseScript([(0.2, join(entry))]))
        return sim.run()

    with pytest.raises(ValueError):          # would loop forever otherwise
        run_with(ModelEntry(ref=ModelRef("kws_res8", name="bad"), fps=-15))
    with pytest.raises(ValueError):
        run_with(ModelEntry(ref=ModelRef("kws_res8", name="bad"), fps=15,
                            depends_on="no_such_model"))
    with pytest.raises(ValueError):
        run_with(ModelEntry(ref=ModelRef("kws_res8", name="bad"), fps=15,
                            trigger_prob=1.5))
