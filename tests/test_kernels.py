"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, sq, n, kv, h, window, softcap)
    (1, 32, 2, 2, 16, None, None),          # MHA baseline
    (2, 40, 4, 2, 16, None, None),          # GQA, non-aligned seq
    (1, 130, 8, 1, 32, None, None),         # MQA, ragged seq
    (2, 64, 4, 4, 64, None, 50.0),          # softcap (gemma2 attn)
    (1, 96, 4, 2, 32, 17, None),            # sliding window
    (1, 128, 8, 2, 64, 64, 30.0),           # window + softcap
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    b, sq, n, kv, h, win, cap = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, n, h), dtype)
    k = jax.random.normal(ks[1], (b, sq, kv, h), dtype)
    v = jax.random.normal(ks[2], (b, sq, kv, h), dtype)
    out = ops.flash_attention(q, k, v, window=win, softcap=cap,
                              block_q=32, block_k=32)
    exp = ref.attention(q, k, v, window=win, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_block_size_invariance():
    b, s, n, kv, h = 1, 128, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, n, h), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, h), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, h), jnp.float32)
    outs = [ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(16, 16), (32, 64), (128, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 64, 4, 2, 16, None),
    (3, 100, 8, 8, 32, None),
    (1, 96, 8, 1, 64, 20),                  # MQA + window
    (2, 256, 4, 4, 64, 128),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_oracle(case, dtype):
    b, s, n, kv, h, win = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, n, h), dtype)
    kc = jax.random.normal(ks[1], (b, s, kv, h), dtype)
    vc = jax.random.normal(ks[2], (b, s, kv, h), dtype)
    pos = jax.random.randint(ks[3], (b,), 0, s)
    out = ops.decode_attention(q, kc, vc, pos, window=win, block_k=32)
    exp = ref.decode_attention(q, kc, vc, pos, window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_decode_attention_ignores_stale_cache():
    """Entries beyond pos must not affect the output."""
    b, s, n, kv, h = 1, 64, 2, 2, 16
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, n, h), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, kv, h), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, kv, h), jnp.float32)
    pos = jnp.array([20], jnp.int32)
    out1 = ops.decode_attention(q, kc, vc, pos, block_k=16)
    kc2 = kc.at[:, 21:].set(999.0)
    vc2 = vc.at[:, 21:].set(-999.0)
    out2 = ops.decode_attention(q, kc2, vc2, pos, block_k=16)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

SSD_CASES = [
    (1, 32, 2, 8, 16, 8),
    (2, 48, 3, 8, 16, 16),
    (1, 100, 2, 16, 32, 32),                # ragged vs chunk
    (2, 64, 4, 32, 64, 64),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_matches_sequential_oracle(case):
    b, s, h, p, n, ch = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    D = 0.5 * jnp.ones((h,), jnp.float32)
    y_ref, fin_ref = ref.ssd(x, dt, A, B, C, D)
    y_k, fin_k = ops.ssd(x, dt, A, B, C, D, chunk=ch)
    np.testing.assert_allclose(y_k, y_ref, atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(fin_k, fin_ref, atol=3e-4, rtol=3e-4)


def test_ssd_chunked_oracle_matches_sequential():
    b, s, h, p, n = 1, 64, 2, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    D = jnp.zeros((h,), jnp.float32)
    y1, f1 = ref.ssd(x, dt, A, B, C, D)
    for chunk in (4, 16, 64):
        y2, f2 = ref.ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
        np.testing.assert_allclose(y2, y1, atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(f2, f1, atol=2e-4, rtol=2e-4)


def test_ssd_carries_state_across_chunks():
    """A long-decay head must propagate influence beyond one chunk."""
    b, s, h, p, n = 1, 32, 1, 4, 8
    x = jnp.zeros((b, s, h, p)).at[0, 0].set(1.0)       # impulse at t=0
    dt = 0.1 * jnp.ones((b, s, h))
    A = jnp.array([-0.01])                               # slow decay
    B = jnp.ones((b, s, n))
    C = jnp.ones((b, s, n))
    D = jnp.zeros((h,))
    y, _ = ops.ssd(x, dt, A, B, C, D, chunk=8)
    assert float(jnp.abs(y[0, -1]).max()) > 1e-3         # crossed 4 chunks


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

GMM_CASES = [
    (16, 8, 16, 2), (37, 16, 24, 4), (100, 32, 64, 8), (64, 16, 48, 16),
]


@pytest.mark.parametrize("case", GMM_CASES)
def test_gmm_matches_oracle(case):
    t, d, f, e = case
    ks = jax.random.split(KEY, 3)
    sizes = jnp.bincount(jax.random.randint(ks[0], (t,), 0, e), length=e)
    x = jax.random.normal(ks[1], (t, d), jnp.float32)
    w = jax.random.normal(ks[2], (e, d, f), jnp.float32)
    out = ops.gmm(x, w, sizes, block_t=16, block_f=16)
    exp = ref.gmm(x, w, sizes)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=3e-5)


def test_gmm_empty_groups():
    """Experts that receive zero tokens must not corrupt neighbours."""
    e, d, f = 4, 8, 8
    sizes = jnp.array([5, 0, 0, 3])
    x = jax.random.normal(KEY, (8, d), jnp.float32)
    w = jax.random.normal(KEY, (e, d, f), jnp.float32)
    out = ops.gmm(x, w, sizes, block_t=4, block_f=8)
    exp = ref.gmm(x, w, sizes)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=3e-5)
